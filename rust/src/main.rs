//! `cagra` — CLI launcher for the cache-optimized graph analytics
//! framework.
//!
//! ```text
//! cagra run     --app pagerank --variant both --graph twitter-sim --iters 20
//! cagra run     --app pagerank --graph twitter-sim --store   # persist preprocessing
//! cagra batch   jobs.txt --store   # many jobs, ONE shared artifact store
//! cagra apps    # list registered applications + variants
//! cagra gen     --graph rmat27-sim --out graph.bin
//! cagra inspect --graph twitter-sim
//! cagra simulate --graph twitter-sim --llc 524288
//! cagra expansion --graph twitter-sim
//! cagra cache stats / cagra cache clear
//! cagra bench ls
//! cagra bench diff baseline.json new.json --tolerance 0.1
//! cagra bench merge out/ --out baseline.json
//! cagra artifacts
//! cagra audit   # repo invariant checker: SAFETY comments, Pod allowlist, …
//! ```

use cagra::apps::registry;
use cagra::bench::diff::{Diff, DiffOptions};
use cagra::bench::report::BenchFile;
use cagra::bench::suite::SUITES;
use cagra::coordinator::{run_job, JobSpec, SystemConfig};
use cagra::graph::datasets;
use cagra::obs::RunReport;
use cagra::reorder;
use cagra::segment;
use cagra::store::ArtifactStore;
use cagra::util::cli::Args;
use cagra::util::{config::Config, fmt_bytes, fmt_count};

const SUBCOMMANDS: &[&str] = &[
    "run", "batch", "serve", "loadgen", "apps", "gen", "inspect", "simulate", "expansion",
    "cache", "bench", "trace", "audit", "artifacts", "help",
];

fn main() {
    let args = Args::from_env(SUBCOMMANDS);
    let result = match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("batch") => cmd_batch(&args),
        Some("serve") => cmd_serve(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("apps") => cmd_apps(),
        Some("gen") => cmd_gen(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("expansion") => cmd_expansion(&args),
        Some("cache") => cmd_cache(&args),
        Some("bench") => cmd_bench(&args),
        Some("trace") => cmd_trace(&args),
        Some("audit") => cmd_audit(&args),
        Some("artifacts") => cmd_artifacts(),
        _ => {
            usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    let apps: Vec<&str> = registry::APPS.iter().map(|a| a.name()).collect();
    println!(
        "cagra — cache-optimized graph analytics (vertex reordering + CSR segmenting)\n\
         \n\
         subcommands:\n\
         \x20 run        run an application       --app <app> [--variant <variant>]  (see `cagra apps`)\n\
         \x20            --graph <dataset> --iters N [--sources N] [--analyze] [--scale F] [--config FILE]\n\
         \x20            [--delta-epsilon F] [--cf-k N] [--damping F] [--bfs-source V]   app-knob overrides\n\
         \x20            [--store] [--store-dir DIR] [--store-cap BYTES] [--no-mmap]   persist preprocessing artifacts\n\
         \x20            [--report FILE] [--pmu]   versioned run report (or CAGRA_RUN_REPORT env)\n\
         \x20            [--failpoints 'site=action@trigger;..']   deterministic fault injection\n\
         \x20            (or CAGRA_FAILPOINTS env; e.g. store.write=err@every:3;worker.job=panic@p:0.1,seed:42)\n\
         \x20 batch      run a job list over ONE shared artifact store    <jobs.txt> [--store ...]\n\
         \x20            file: one `app=<name> [variant=..] [graph=..] [iters=N] [scale=F]\n\
         \x20            [sources=N] [analyze=true] [delta-epsilon=F] [cf-k=N] [damping=F]\n\
         \x20            [bfs-source=V]` line per job; # comments\n\
         \x20            [--report-dir DIR] [--pmu]   one run report per job + a rollup\n\
         \x20 serve      resident daemon: NDJSON requests over TCP or stdio (see rust/README.md)\n\
         \x20            [--addr HOST:PORT] [--workers N] [--queue-cap N] [--mem-cap BYTES]\n\
         \x20            [--port-file FILE] [--stdio] [--store ...] [--max-conns N] [--idle-timeout-ms N]\n\
         \x20 loadgen    closed-loop serve client   --addr HOST:PORT [--clients N] [--requests N]\n\
         \x20            [--app <app>] [--variant V] [--graph D] [--iters N] [--scale F] [--shutdown]\n\
         \x20            [--retry-max N] [--retry-base-ms N] [--seed N] [--allow-failures]\n\
         \x20 apps       list registered applications and their variants\n\
         \x20 gen        generate + cache a dataset  --graph <dataset> [--out file.bin] [--scale F]\n\
         \x20 inspect    dataset statistics          --graph <dataset>\n\
         \x20 simulate   memory-system simulation    --graph <dataset> [--llc BYTES]\n\
         \x20 expansion  expansion-factor sweep      --graph <dataset> [--random-seed N]\n\
         \x20 cache      artifact store tools        stats (default) | clear  [--store-dir DIR]\n\
         \x20 bench      bench-result tools          ls [--names] | diff <baseline> <new> [--tolerance F]\n\
         \x20            [--sigma F] [--allow-missing] | merge <file-or-dir>... --out FILE\n\
         \x20 trace      inspect a run report        <report.json> [--chrome out.json]\n\
         \x20 audit      invariant checker (DESIGN.md §7)   [paths…] [--fix-list]\n\
         \x20            no paths: audit the whole crate (src/, benches/, tests/); exits 1 on findings\n\
         \x20 artifacts  list PJRT artifacts and check they compile\n\
         \n\
         apps:     {}\n\
         datasets: {}",
        apps.join(", "),
        datasets::ALL.join(", ")
    );
}

/// `cagra apps`: the registry rendered as help text. Because this reads
/// the same variant tables the parser uses, the listing cannot drift
/// from what `--app`/`--variant` accept.
fn cmd_apps() -> anyhow::Result<()> {
    println!("registered applications (cagra run --app <name> [--variant <variant>]):");
    for app in registry::APPS {
        let aliases = if app.aliases().is_empty() {
            String::new()
        } else {
            format!(" (aliases: {})", app.aliases().join(", "))
        };
        println!("\n  {}{aliases}\n      {}", app.name(), app.description());
        for v in app.variants() {
            let mut notes = Vec::new();
            if v.kind == app.default_variant() {
                notes.push("default".to_string());
            }
            if !v.aliases.is_empty() {
                notes.push(format!("aliases: {}", v.aliases.join(", ")));
            }
            if app.uses_store(v.kind) {
                notes.push("store-cacheable".to_string());
            }
            let notes = if notes.is_empty() {
                String::new()
            } else {
                format!("  [{}]", notes.join("; "))
            };
            println!("      --variant {:<16}{notes}", v.name);
        }
    }
    Ok(())
}

fn system_config(args: &Args) -> anyhow::Result<SystemConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => SystemConfig::from_config(&Config::load(path)?)?,
        None => SystemConfig::default(),
    };
    if let Some(llc) = args.get("llc") {
        cfg.llc_bytes = llc.parse()?;
    }
    if args.has_flag("store") {
        cfg.store_enabled = true;
    }
    if let Some(dir) = args.get("store-dir") {
        cfg.store_dir = dir.to_string();
        cfg.store_enabled = true;
    }
    if let Some(cap) = args.get("store-cap") {
        cfg.store_cap_bytes = cap.parse()?;
    }
    if args.has_flag("no-mmap") {
        cfg.store_mmap = false;
    }
    if let Some(seed) = args.get("random-seed") {
        cfg.random_seed = seed.parse()?;
    }
    if let Some(spec) = args.get("failpoints") {
        cfg.failpoints = spec.to_string();
    }
    // Arm immediately so every command runs under the requested fault
    // pressure (`CAGRA_FAILPOINTS` overrides; an empty spec disarms).
    cagra::fault::arm_from(&cfg.failpoints)?;
    Ok(cfg)
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let cfg = system_config(args)?;
    let app_name = args.get_or("app", "pagerank");
    let app = registry::find(app_name)
        .ok_or_else(|| anyhow::anyhow!("unknown app {app_name:?} (see `cagra apps`)"))?;
    let kind = match args.get("variant") {
        Some(v) => app.parse_variant(v)?,
        None => app.default_variant(),
    };
    // Run-report destination: flag wins, env var (CI, wrappers) backs it.
    let report_path = args
        .get("report")
        .map(str::to_string)
        .or_else(|| std::env::var("CAGRA_RUN_REPORT").ok())
        .filter(|p| !p.is_empty());
    let knobs = parse_knobs(args)?;
    let spec = JobSpec {
        dataset: args.get_or("graph", "livejournal-sim").to_string(),
        app: kind,
        iters: args.get_usize("iters", 10),
        num_sources: args.get_usize("sources", 12),
        analyze_memory: args.has_flag("analyze"),
        collect_pmu: args.has_flag("pmu"),
        scale: args.get_f64("scale", 1.0),
        delta_epsilon: knobs.delta_epsilon,
        cf_k: knobs.cf_k,
        damping: knobs.damping,
        bfs_source: knobs.bfs_source,
    };
    println!(
        "running {}/{} on {} ({}), llc={}",
        spec.app.app_name(),
        spec.app.variant_name(),
        spec.dataset,
        datasets::paper_name(&spec.dataset),
        fmt_bytes(cfg.llc_bytes)
    );
    if report_path.is_some() {
        cagra::obs::recorder::enable();
    }
    let result = run_job(&spec, &cfg)?;
    print!("{}", result.metrics.render());
    println!("summary value: {:.6}", result.summary);
    if let Some(path) = report_path {
        let report = RunReport::from_job(&spec, &result);
        cagra::obs::recorder::disable();
        report.write(std::path::Path::new(&path))?;
        println!(
            "wrote run report {path} ({} events, {} dropped, stall source: {})",
            report.events.len(),
            report.events_dropped,
            report.stall_source()
        );
    }
    Ok(())
}

/// The JobSpec-level app-knob overrides shared by `cagra run` (direct)
/// and `cagra batch` (as defaults for jobs without their own override).
#[derive(Default)]
struct KnobOverrides {
    delta_epsilon: Option<f64>,
    cf_k: Option<usize>,
    damping: Option<f64>,
    bfs_source: Option<u32>,
}

fn parse_knob<T: std::str::FromStr>(args: &Args, key: &str) -> anyhow::Result<Option<T>> {
    args.get(key)
        .map(|v| {
            v.parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}"))
        })
        .transpose()
}

fn parse_knobs(args: &Args) -> anyhow::Result<KnobOverrides> {
    Ok(KnobOverrides {
        delta_epsilon: parse_knob(args, "delta-epsilon")?,
        cf_k: parse_knob(args, "cf-k")?,
        damping: parse_knob(args, "damping")?,
        bfs_source: parse_knob(args, "bfs-source")?,
    })
}

/// `cagra batch <file>`: run a list of jobs over ONE long-lived artifact
/// store, so later jobs warm-hit earlier jobs' preprocessing (per-job
/// eviction-exemption scopes are released as each job completes).
fn cmd_batch(args: &Args) -> anyhow::Result<()> {
    let cfg = system_config(args)?;
    let Some(file) = args.positional.first() else {
        anyhow::bail!(
            "usage: cagra batch <jobs.txt> [--store] [--store-dir DIR] [--delta-epsilon F]\n\
             (one `app=<name> [variant=..] [graph=..] [iters=N] ...` line per job)"
        );
    };
    let text = std::fs::read_to_string(file)
        .map_err(|e| anyhow::anyhow!("reading batch file {file}: {e}"))?;
    let mut specs = cagra::coordinator::parse_batch(&text)?;
    // CLI-level defaults for jobs that don't carry their own override.
    let knobs = parse_knobs(args)?;
    for s in &mut specs {
        if let Some(eps) = knobs.delta_epsilon {
            s.delta_epsilon.get_or_insert(eps);
        }
        if let Some(k) = knobs.cf_k {
            s.cf_k.get_or_insert(k);
        }
        if let Some(d) = knobs.damping {
            s.damping.get_or_insert(d);
        }
        if let Some(src) = knobs.bfs_source {
            s.bfs_source.get_or_insert(src);
        }
    }
    if args.has_flag("pmu") {
        for s in &mut specs {
            s.collect_pmu = true;
        }
    }
    println!(
        "batch: {} job(s) from {file}; artifact store {}",
        specs.len(),
        if cfg.store_enabled {
            "shared across the batch"
        } else {
            "disabled (pass --store to share preprocessing)"
        }
    );
    let report_dir = args.get("report-dir").map(std::path::PathBuf::from);
    let results = match &report_dir {
        Some(dir) => {
            std::fs::create_dir_all(dir)
                .map_err(|e| anyhow::anyhow!("creating {}: {e}", dir.display()))?;
            cagra::obs::recorder::enable();
            // Per-job reports must be built inside the callback: the
            // recorder ring only holds one job's events at a time.
            let mut rollup = Vec::new();
            let results = cagra::coordinator::run_batch_with(&specs, &cfg, |i, spec, r| {
                let name = format!(
                    "RUN_{:03}_{}-{}.json",
                    i + 1,
                    spec.app.app_name(),
                    spec.app.variant_name().replace('+', "-")
                );
                let report = RunReport::from_job(spec, r);
                report.write(&dir.join(&name))?;
                rollup.push((name, report));
                Ok(())
            });
            cagra::obs::recorder::disable();
            let results = results?;
            write_batch_rollup(dir, &rollup)?;
            println!(
                "wrote {} run report(s) + ROLLUP.json to {}",
                rollup.len(),
                dir.display()
            );
            results
        }
        None => cagra::coordinator::run_batch(&specs, &cfg)?,
    };
    for (i, (spec, r)) in specs.iter().zip(&results).enumerate() {
        println!(
            "\n[job {}/{}] {}/{} on {} (scale {})",
            i + 1,
            specs.len(),
            spec.app.app_name(),
            spec.app.variant_name(),
            spec.dataset,
            spec.scale
        );
        print!("{}", r.metrics.render());
        println!("summary value: {:.6}", r.summary);
    }
    // The store counters are cumulative across the batch; the last
    // store-using job's snapshot is the batch total.
    if let Some(s) = results.iter().rev().find_map(|r| r.metrics.store) {
        println!(
            "\nbatch store totals: {} hits, {} misses, {} evictions; {} entries ({})",
            s.hits,
            s.misses,
            s.evictions,
            s.entries,
            fmt_bytes(s.resident_bytes as usize)
        );
    }
    Ok(())
}

/// `cagra serve`: the resident daemon — newline-delimited JSON requests
/// over TCP (or stdio with `--stdio`) executed by a worker pool that
/// shares one disk store and one in-memory artifact layer, so repeated
/// requests skip dataset loading and CSR decoding entirely.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let cfg = system_config(args)?;
    let opts = cagra::serve::ServeOpts {
        addr: args.get_or("addr", "127.0.0.1:7421").to_string(),
        workers: args.get_usize("workers", 4),
        queue_cap: args.get_usize("queue-cap", 64),
        mem_budget: args.get_u64("mem-cap", 0),
        port_file: args.get("port-file").map(str::to_string),
        stdio: args.has_flag("stdio"),
        max_conns: args.get_usize("max-conns", 1024),
        idle_timeout_ms: args.get_u64("idle-timeout-ms", 60_000),
    };
    cagra::serve::serve(cfg, &opts)
}

/// `cagra loadgen`: closed-loop client for a running daemon — N
/// connections each issuing M validated requests back-to-back, reporting
/// jobs/sec and latency percentiles.
fn cmd_loadgen(args: &Args) -> anyhow::Result<()> {
    use cagra::util::json::Value;
    let Some(addr) = args.get("addr") else {
        anyhow::bail!(
            "usage: cagra loadgen --addr HOST:PORT [--clients N] [--requests N] \
             [--app <app>] [--variant V] [--graph D] [--iters N] [--scale F] \
             [--deadline-ms N] [--retry-max N] [--retry-base-ms N] [--seed N] \
             [--allow-failures] [--shutdown]"
        );
    };
    let mut fields = vec![
        ("op".to_string(), Value::Str("run".to_string())),
        (
            "app".to_string(),
            Value::Str(args.get_or("app", "pagerank").to_string()),
        ),
    ];
    if let Some(v) = args.get("variant") {
        fields.push(("variant".to_string(), Value::Str(v.to_string())));
    }
    fields.push((
        "graph".to_string(),
        Value::Str(args.get_or("graph", "livejournal-sim").to_string()),
    ));
    fields.push(("iters".to_string(), Value::Num(args.get_usize("iters", 3) as f64)));
    fields.push(("scale".to_string(), Value::Num(args.get_f64("scale", 1.0))));
    if let Some(ms) = parse_knob::<u64>(args, "deadline-ms")? {
        fields.push(("deadline_ms".to_string(), Value::Num(ms as f64)));
    }
    let opts = cagra::serve::LoadgenOpts {
        addr: addr.to_string(),
        clients: args.get_usize("clients", 4),
        requests: args.get_usize("requests", 8),
        request: Value::Obj(fields),
        shutdown_after: args.has_flag("shutdown"),
        retry_max: args.get_usize("retry-max", 3),
        retry_base_ms: args.get_u64("retry-base-ms", 10),
        seed: args.get_u64("seed", 0x10AD),
        allow_failures: args.has_flag("allow-failures"),
    };
    let report = cagra::serve::loadgen::run(&opts)?;
    print!("{}", report.render());
    Ok(())
}

/// One `ROLLUP.json` per batch: which per-job reports were written and
/// each job's headline numbers, so dashboards can index a report
/// directory without parsing every file.
fn write_batch_rollup(dir: &std::path::Path, jobs: &[(String, RunReport)]) -> anyhow::Result<()> {
    use cagra::util::json::Value;
    let rows = jobs
        .iter()
        .map(|(file, r)| {
            Value::Obj(vec![
                ("file".to_string(), Value::Str(file.clone())),
                ("app".to_string(), Value::Str(r.app.clone())),
                ("dataset".to_string(), Value::Str(r.dataset.clone())),
                ("summary".to_string(), Value::Num(r.summary)),
                ("events".to_string(), Value::Num(r.events.len() as f64)),
                (
                    "stall_source".to_string(),
                    Value::Str(r.stall_source().to_string()),
                ),
            ])
        })
        .collect();
    let rollup = Value::Obj(vec![
        ("format".to_string(), Value::Str("cagra-run-rollup".to_string())),
        ("version".to_string(), Value::Num(1.0)),
        ("jobs".to_string(), Value::Arr(rows)),
    ]);
    let path = dir.join("ROLLUP.json");
    std::fs::write(&path, rollup.render() + "\n")
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
}

/// `cagra trace <report.json>`: summarize a run report; `--chrome FILE`
/// additionally exports the event timeline in Chrome `trace_event`
/// format (chrome://tracing, Perfetto).
fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    let Some(path) = args.positional.first() else {
        anyhow::bail!("usage: cagra trace <run-report.json> [--chrome out.json]");
    };
    let report = RunReport::load(std::path::Path::new(path))?;
    println!("run report {path}");
    println!("  app: {}  dataset: {} (scale {})", report.app, report.dataset, report.scale);
    println!(
        "  threads: {}  edges: {}  summary: {:.6}",
        report.threads,
        fmt_count(report.edges),
        report.summary
    );
    println!("  stall source: {}", report.stall_source());
    println!("  events: {} ({} dropped)", report.events.len(), report.events_dropped);
    for p in &report.phases {
        println!("    {:<24} {:>9.4}s  x{}", p.name, p.seconds, p.count);
    }
    if let Some(out) = args.get("chrome") {
        std::fs::write(out, cagra::obs::chrome::chrome_trace(&report))
            .map_err(|e| anyhow::anyhow!("writing {out}: {e}"))?;
        println!("wrote Chrome trace {out} (load in chrome://tracing or Perfetto)");
    }
    Ok(())
}

fn cmd_gen(args: &Args) -> anyhow::Result<()> {
    let name = args.get_or("graph", "livejournal-sim");
    let scale = args.get_f64("scale", 1.0);
    let ds = datasets::load_scaled(name, scale)?;
    println!(
        "{name}: {} vertices, {} edges",
        fmt_count(ds.graph.num_vertices() as u64),
        fmt_count(ds.graph.num_edges() as u64)
    );
    if let Some(out) = args.get("out") {
        let edges: Vec<_> = ds.graph.edges().collect();
        cagra::graph::edgelist::write_binary(out, ds.graph.num_vertices(), &edges)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let name = args.get_or("graph", "livejournal-sim");
    let ds = datasets::load_scaled(name, args.get_f64("scale", 1.0))?;
    let g = &ds.graph;
    let degs = g.out_degrees();
    let maxd = degs.iter().copied().max().unwrap_or(0);
    println!("dataset {name} (stand-in for {})", datasets::paper_name(name));
    println!("  vertices: {}", fmt_count(g.num_vertices() as u64));
    println!("  edges:    {}", fmt_count(g.num_edges() as u64));
    println!("  avg deg:  {:.1}", g.num_edges() as f64 / g.num_vertices() as f64);
    println!("  max deg:  {}", fmt_count(maxd as u64));
    println!("  csr size: {}", fmt_bytes(g.bytes()));
    println!("  vertex data (f64): {}", fmt_bytes(g.num_vertices() * 8));
    println!("  degree histogram (log2 buckets):");
    for (b, c) in cagra::graph::generators::degree_histogram(&degs) {
        println!("    2^{b:<2} {}", fmt_count(c as u64));
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let cfg = system_config(args)?;
    let name = args.get_or("graph", "livejournal-sim");
    let ds = datasets::load_scaled(name, args.get_f64("scale", 1.0))?;
    let g = &ds.graph;
    println!(
        "simulating PageRank memory behaviour on {name} (LLC {})",
        fmt_bytes(cfg.llc_bytes)
    );
    use cagra::apps::pagerank::Variant;
    for v in Variant::all() {
        let est = cagra::coordinator::job::simulate_pagerank(g, &cfg, *v);
        println!(
            "  {:<24} {:>8.2} stall-cyc/access   LLC miss {:>5.1}%",
            v.name(),
            est.stalls_per_access(),
            est.llc_miss_rate * 100.0
        );
    }
    Ok(())
}

fn cmd_expansion(args: &Args) -> anyhow::Result<()> {
    let cfg = system_config(args)?;
    let name = args.get_or("graph", "twitter-sim");
    let ds = datasets::load_scaled(name, args.get_f64("scale", 1.0))?;
    let g = &ds.graph;
    let counts = [1usize, 2, 4, 8, 16, 32, 64, 128];
    println!("expansion factors for {name} (Figure 7):");
    for (order_name, graph) in [
        ("original", g.clone()),
        ("degree-sorted", reorder::reorder(g, reorder::Ordering::DegreeSort).0),
        (
            "random",
            reorder::reorder_seeded(g, reorder::Ordering::Random, cfg.random_seed).0,
        ),
    ] {
        let sweep = segment::expansion::expansion_sweep(&graph, &counts);
        let row: Vec<String> = sweep.iter().map(|(k, q)| format!("{k}:{q:.2}")).collect();
        println!("  {order_name:<14} {}", row.join("  "));
    }
    Ok(())
}

fn cmd_cache(args: &Args) -> anyhow::Result<()> {
    let cfg = system_config(args)?;
    // Inspection only: never create the directory or sweep temp files —
    // a typo'd --store-dir must not plant an empty store there.
    let store = match ArtifactStore::open_existing(&cfg.store_dir, cfg.store_cap_bytes) {
        Ok(s) => s,
        Err(_) => {
            println!(
                "no artifact store at {} (nothing has been cached yet — run with --store)",
                cfg.store_dir
            );
            return Ok(());
        }
    };
    match args.positional.first().map(String::as_str) {
        Some("clear") => {
            let (removed, freed) = store.clear()?;
            println!(
                "cleared {removed} artifacts ({}) from {}",
                fmt_bytes(freed as usize),
                store.dir().display()
            );
        }
        Some("stats") | None => {
            let s = store.stats();
            println!("artifact store at {}", store.dir().display());
            println!("  entries:  {}", s.entries);
            let cap = if s.cap_bytes == 0 {
                "unlimited".to_string()
            } else {
                fmt_bytes(s.cap_bytes as usize)
            };
            println!("  resident: {} (cap {cap})", fmt_bytes(s.resident_bytes as usize));
            println!(
                "  mmap:     {} on this platform",
                if cagra::store::mmap_supported() { "supported" } else { "unsupported" }
            );
            // On-disk count: per-process counters are useless from a
            // fresh inspection process, but the evidence files persist.
            let q = store.quarantine_count();
            if q > 0 {
                println!(
                    "  quarantine: {q} corrupt artifact(s) set aside in {}/.quarantine",
                    store.dir().display()
                );
            }
            let arts = store.list_artifacts();
            if !arts.is_empty() {
                println!("  artifacts (codec v{}):", cagra::store::CODEC_VERSION);
                for a in arts {
                    let version = match a.version {
                        Some(v) => format!("v{v}"),
                        None => "v?".to_string(),
                    };
                    println!(
                        "    {:<56} {:>10}  {:<4} {:<4} {}",
                        a.file,
                        fmt_bytes(a.size as usize),
                        version,
                        a.kind.as_deref().unwrap_or("?"),
                        if a.mappable { "mapped warm load" } else { "decoded warm load" }
                    );
                }
            }
        }
        Some(other) => anyhow::bail!("unknown cache action {other:?} (expected stats|clear)"),
    }
    Ok(())
}

/// `cagra bench`: machine-readable bench-result tools.
///
/// - `ls` renders the suite registry (the same one every bench target
///   runs through, so the listing cannot drift from the actual targets).
/// - `diff <baseline> <new>` compares two report files — or directories
///   of `BENCH_*.json` — with the noise-aware comparator and **exits 2**
///   when any case regresses beyond tolerance (CI's perf gate).
/// - `merge <inputs>... --out FILE` combines per-suite reports into one
///   file (how `rust/bench-baseline.json` is refreshed).
fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("ls") => cmd_bench_ls(args),
        Some("diff") => cmd_bench_diff(args),
        Some("merge") => cmd_bench_merge(args),
        Some(other) => anyhow::bail!("unknown bench action {other:?} (expected ls|diff|merge)"),
        None => {
            anyhow::bail!("usage: cagra bench ls | diff <base> <new> | merge <in>... --out FILE")
        }
    }
}

fn cmd_bench_ls(args: &Args) -> anyhow::Result<()> {
    // `--names`: machine-readable one-per-line listing (CI derives the
    // expected report count from it instead of hardcoding it).
    if args.has_flag("names") {
        for suite in SUITES {
            println!("{}", suite.name);
        }
        return Ok(());
    }
    println!(
        "registered bench suites (cargo bench --bench <name>; each emits BENCH_<name>.json):"
    );
    for suite in SUITES {
        println!("\n  {}  [{}]\n      {}", suite.name, suite.paper_ref, suite.title);
        println!("      scopes: {}", suite.scopes);
        println!("      cases:  {}", suite.cases.join(", "));
    }
    println!("\n{} suites; knobs: CAGRA_BENCH_SCALE/_REPS/_WARMUP/_OUT", SUITES.len());
    Ok(())
}

fn cmd_bench_diff(args: &Args) -> anyhow::Result<()> {
    let (Some(base_path), Some(new_path)) = (args.positional.get(1), args.positional.get(2))
    else {
        anyhow::bail!(
            "usage: cagra bench diff <baseline.json|dir> <new.json|dir> \
             [--tolerance F] [--sigma F] [--allow-missing]"
        );
    };
    let baseline = BenchFile::load_path(std::path::Path::new(base_path))?;
    let new = BenchFile::load_path(std::path::Path::new(new_path))?;
    let opts = DiffOptions {
        tolerance: args.get_f64("tolerance", 0.10),
        sigma: args.get_f64("sigma", 2.0),
        fail_on_missing: !args.has_flag("allow-missing"),
    };
    let diff = Diff::compare(&baseline, &new, opts);
    print!("{}", diff.render());
    if diff.is_regression() {
        eprintln!(
            "perf regression: {} case(s) beyond tolerance (see table above)",
            diff.failures().len()
        );
        std::process::exit(2);
    }
    Ok(())
}

fn cmd_bench_merge(args: &Args) -> anyhow::Result<()> {
    let inputs = &args.positional[1..];
    if inputs.is_empty() {
        anyhow::bail!("usage: cagra bench merge <file-or-dir>... --out FILE");
    }
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("--out FILE is required"))?;
    let files = inputs
        .iter()
        .map(|p| BenchFile::load_path(std::path::Path::new(p)))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let mut merged = BenchFile::merge(files)?;
    merged.note = format!("merged from {} input(s) by `cagra bench merge`", inputs.len());
    std::fs::write(out, merged.to_json()?)?;
    println!(
        "wrote {out}: {} suite(s), {} case(s)",
        merged.suites.len(),
        merged.case_count()
    );
    Ok(())
}

/// `cagra audit`: run the in-tree invariant checker (DESIGN.md §7).
///
/// With no positional paths, audits the whole crate the way CI does
/// (resolving the crate dir from the current directory, so it works from
/// both the repo root and `rust/`). With paths, audits just those files
/// or directories — the incremental pre-commit workflow. `--fix-list`
/// switches to a terse `file:line:lint` listing for tooling.
fn cmd_audit(args: &Args) -> anyhow::Result<()> {
    use cagra::audit;

    let report = if args.positional.is_empty() {
        let cwd = std::env::current_dir()?;
        audit::audit_tree(&cwd)?
    } else {
        let paths: Vec<std::path::PathBuf> =
            args.positional.iter().map(std::path::PathBuf::from).collect();
        let base = std::env::current_dir()?;
        audit::audit_paths(&base, &paths)?
    };

    if args.has_flag("fix-list") {
        for d in &report.diagnostics {
            println!("{}:{}:{}", d.file, d.line, d.lint);
        }
    } else {
        for d in &report.diagnostics {
            println!("{d}");
        }
        if report.clean() {
            println!(
                "audit OK: {} file(s) scanned, {} unsafe site(s) audited, 0 findings",
                report.files_scanned, report.unsafe_sites
            );
        } else {
            println!(
                "audit FAILED: {} finding(s) across {} file(s) scanned \
                 ({} unsafe site(s) audited)",
                report.diagnostics.len(),
                report.files_scanned,
                report.unsafe_sites
            );
        }
    }
    if !report.clean() {
        anyhow::bail!("audit found {} violation(s)", report.diagnostics.len());
    }
    Ok(())
}

fn cmd_artifacts() -> anyhow::Result<()> {
    let mut rt = cagra::runtime::Runtime::from_env()?;
    println!("PJRT platform: {}", rt.platform());
    let names: Vec<String> = rt.available().iter().map(|s| s.to_string()).collect();
    if names.is_empty() {
        println!("no artifacts found — run `make artifacts`");
        return Ok(());
    }
    for name in names {
        let exe = rt.load(&name)?;
        println!(
            "  {name}: inputs {:?} outputs {:?} params {:?} — compiles OK",
            exe.meta.inputs, exe.meta.outputs, exe.meta.params
        );
    }
    Ok(())
}
