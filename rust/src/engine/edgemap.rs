//! Direction-switching EdgeMap and VertexMap (the Ligra API our framework
//! extends — the paper's BFS/BC numbers ride on "its innovative push and
//! pull switch optimization", §6.2).
//!
//! `edge_map` applies `update(src, dst) -> bool` over the edges leaving
//! the frontier, gated by `cond(dst)`; returns the new frontier (vertices
//! for which some update returned true).
//!
//! - **Push (sparse)**: iterate frontier vertices' out-edges; updates may
//!   race, so `update` must be CAS-style idempotent. Work is distributed
//!   with the §3.2 cost-based scheduler keyed on out-degree — a
//!   statically-chunked split starves threads whenever degree skew piles
//!   the frontier's heavy vertices into one chunk.
//! - **Pull (dense)**: iterate *all* destinations with `cond(dst)`,
//!   scanning in-edges for frontier members — no write races, and early
//!   exit once `cond` is satisfied.
//!
//! The switch uses Ligra's heuristic: pull when
//! `|frontier| + outEdges(frontier) > |E| / threshold_den`. Both the
//! switch and the two modes are **allocation-free in the steady state**:
//! every buffer (output flags, membership probes, id lists, the degree
//! prefix) comes from the caller's [`EngineScratch`], and the switch
//! estimates frontier work by visiting members in place instead of
//! materializing an id vector. See [`super::scratch`] for the ownership
//! and reset contract.

use super::frontier::VertexSubset;
use super::scratch::EngineScratch;
use crate::graph::{Csr, VertexId};
use crate::parallel::{parallel_for, parallel_for_cost, UnsafeSlice};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// EdgeMap tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct EdgeMapOpts {
    /// Pull when frontier work exceeds |E| / threshold_den (Ligra uses 20).
    pub threshold_den: u64,
    /// Keep the output frontier as a bitvector (Tables 7/8's "Bitvector"
    /// optimization) instead of a dense bool vector.
    pub bitvector_frontier: bool,
}

impl Default for EdgeMapOpts {
    fn default() -> Self {
        EdgeMapOpts {
            threshold_den: 20,
            bitvector_frontier: false,
        }
    }
}

/// Membership probe over the frontier for pull mode: either the dense
/// byte form or the packed bitvector (§6.3), borrowed from the input
/// frontier when representations already match, else populated
/// touched-only into the scratch.
enum Probe<'a> {
    Flags(&'a [bool]),
    Words(&'a [u64]),
}

impl Probe<'_> {
    #[inline]
    fn contains(&self, v: VertexId) -> bool {
        match self {
            Probe::Flags(f) => f[v as usize],
            Probe::Words(w) => (w[v as usize / 64] >> (v as usize % 64)) & 1 == 1,
        }
    }
}

// audit: hot-path — everything to the end marker runs once per traversal
// level; the zero-alloc steady state (module docs) is machine-enforced
// here by `cagra audit`'s hot-path-alloc lint. Pooled growth
// (resize/reserve/push to high-water marks) is allowed; fresh-storage
// idioms are not.
/// Apply `update` over edges out of `frontier`; `g` is the out-edge CSR
/// and `g_in` its transpose (used for pull mode). Returns the new
/// frontier, whose storage is drawn from `scratch` — hand exhausted
/// frontiers back via [`EngineScratch::recycle`] so the steady state
/// allocates nothing.
pub fn edge_map<U, C>(
    g: &Csr,
    g_in: &Csr,
    frontier: &VertexSubset,
    update: U,
    cond: C,
    opts: EdgeMapOpts,
    scratch: &mut EngineScratch,
) -> VertexSubset
where
    U: Fn(VertexId, VertexId) -> bool + Sync,
    C: Fn(VertexId) -> bool + Sync,
{
    assert_eq!(
        scratch.n(),
        g.num_vertices(),
        "EngineScratch sized for a different graph"
    );
    let t0 = crate::obs::recorder::timestamp();
    let m = g.num_edges() as u64;
    // Direction heuristic: count and degree-sum the members. Sparse
    // frontiers are read in place; dense forms are materialized into a
    // pooled id vector during this same pass, so a dense→push transition
    // traverses the frontier exactly once (push takes ownership of the
    // list; pull returns it to the pool unused).
    let (count, out_work, owned): (usize, u64, Option<Vec<VertexId>>) =
        match frontier.as_sparse_ids() {
            Some(ids) => (
                ids.len(),
                ids.iter().map(|&v| g.degree(v) as u64).sum::<u64>(),
                None,
            ),
            None => {
                let mut ids = scratch.take_ids();
                let mut w = 0u64;
                frontier.for_each(|v| {
                    w += g.degree(v) as u64;
                    ids.push(v);
                });
                (ids.len(), w, Some(ids))
            }
        };
    let dense = out_work + count as u64 > m / opts.threshold_den.max(1);
    let out = if dense {
        if let Some(ids) = owned {
            scratch.put_ids(ids);
        }
        edge_map_pull(g_in, frontier, update, cond, opts, scratch)
    } else {
        edge_map_push(g, frontier, owned, out_work, update, cond, scratch)
    };
    // O(1): the new frontier's count is cached at construction.
    let next = out.count() as u64;
    crate::obs::recorder::record_edge_map_level(t0, count as u64, out_work, next, dense);
    out
}

/// Push mode: cost-balanced parallel loop over frontier vertices,
/// scattering updates. The new frontier is collected at an atomic cursor
/// (no O(n) flag rescan), and the shared `out_flags` are reset
/// touched-only from the collected ids.
fn edge_map_push<U, C>(
    g: &Csr,
    frontier: &VertexSubset,
    owned: Option<Vec<VertexId>>,
    out_work: u64,
    update: U,
    cond: C,
    scratch: &mut EngineScratch,
) -> VertexSubset
where
    U: Fn(VertexId, VertexId) -> bool + Sync,
    C: Fn(VertexId) -> bool + Sync,
{
    let n = g.num_vertices();
    // `owned` is the pooled materialization the direction switch already
    // built for non-sparse frontiers; sparse storage is borrowed.
    // Winner ids land in the persistent slots buffer at an atomic cursor.
    // Every winner accounts for at least one scanned edge, so `out_work`
    // (capped at n) bounds the cursor; the buffer grows to its high-water
    // length once and is never zero-filled — only `new_len` slots are
    // written and read per call.
    let cap = (out_work as usize).min(n);
    if scratch.push_slots.len() < cap {
        scratch.push_slots.resize(cap, 0);
    }
    let new_len = {
        let ids: &[VertexId] = owned
            .as_deref()
            .unwrap_or_else(|| frontier.as_sparse_ids().unwrap());
        // Out-degree prefix for the §3.2 cost-based split (+1 per vertex
        // so zero-degree stretches still subdivide). Rebuilt in the
        // reusable buffer every call.
        let prefix = &mut scratch.cost_prefix;
        prefix.clear();
        prefix.reserve(ids.len() + 1);
        prefix.push(0);
        let mut acc = 0u64;
        for &v in ids {
            acc += g.degree(v) as u64 + 1;
            prefix.push(acc);
        }
        let prefix: &[u64] = prefix;
        let threshold = (acc / (4 * crate::parallel::num_threads() as u64).max(1)).max(256);
        let cursor = AtomicUsize::new(0);
        let slots = UnsafeSlice::new(&mut scratch.push_slots);
        let out_flags: &[AtomicBool] = &scratch.out_flags;
        parallel_for_cost(
            ids.len(),
            threshold,
            |lo, hi| prefix[hi] - prefix[lo],
            |lo, hi| {
                for &s in &ids[lo..hi] {
                    for &d in g.neighbors(s) {
                        if cond(d)
                            && update(s, d)
                            && !out_flags[d as usize].swap(true, Ordering::Relaxed)
                        {
                            let k = cursor.fetch_add(1, Ordering::Relaxed);
                            // SAFETY: each k handed to exactly one task;
                            // k < cap because winners are distinct and
                            // each consumes one of `out_work` edges.
                            unsafe { slots.write(k, d) };
                        }
                    }
                }
            },
        );
        let new_len = cursor.into_inner();
        debug_assert!(new_len <= cap);
        new_len
    };
    // Copy the winners into a pooled id vector (O(new frontier), not
    // O(cap)) and reset exactly their flags — touched-only.
    let mut out_ids = scratch.take_ids();
    out_ids.extend_from_slice(&scratch.push_slots[..new_len]);
    for &d in &out_ids {
        // audit: relaxed-ok — reset happens after the parallel region
        // joined (run_on_all returns only when every worker is done), so
        // no thread can observe the flag concurrently.
        scratch.out_flags[d as usize].store(false, Ordering::Relaxed);
    }
    if let Some(ids) = owned {
        scratch.put_ids(ids);
    }
    VertexSubset::from_ids(n, out_ids)
}

/// Pull mode: parallel over all destinations satisfying `cond`, scanning
/// in-neighbors for frontier membership. The membership probe borrows the
/// input frontier's storage when its representation already matches the
/// requested one, else it is populated (and afterwards cleared,
/// touched-only for sparse inputs) in the scratch; the output flags come
/// from the scratch's buffer pool.
fn edge_map_pull<U, C>(
    g_in: &Csr,
    frontier: &VertexSubset,
    update: U,
    cond: C,
    opts: EdgeMapOpts,
    scratch: &mut EngineScratch,
) -> VertexSubset
where
    U: Fn(VertexId, VertexId) -> bool + Sync,
    C: Fn(VertexId) -> bool + Sync,
{
    let n = g_in.num_vertices();
    let want_words = opts.bitvector_frontier;
    let mut out = scratch.take_flags();
    // 1. Populate the probe when the input representation does not match
    //    the requested one (touched-only writes).
    match frontier {
        VertexSubset::Sparse { ids, .. } => {
            if want_words {
                for &v in ids {
                    scratch.member_words[v as usize / 64] |= 1u64 << (v as usize % 64);
                }
            } else {
                for &v in ids {
                    scratch.member_flags[v as usize] = true;
                }
            }
        }
        VertexSubset::Dense { flags, .. } if want_words => {
            for (v, &b) in flags.iter().enumerate() {
                if b {
                    scratch.member_words[v / 64] |= 1u64 << (v % 64);
                }
            }
        }
        VertexSubset::Bits { .. } if !want_words => {
            frontier.for_each(|v| scratch.member_flags[v as usize] = true);
        }
        _ => {} // representation matches: borrow directly below
    }
    // 2. The parallel pull sweep.
    {
        let probe = match (frontier, want_words) {
            (VertexSubset::Dense { flags, .. }, false) => Probe::Flags(flags),
            (VertexSubset::Bits { words, .. }, true) => Probe::Words(words),
            (_, false) => Probe::Flags(&scratch.member_flags),
            (_, true) => Probe::Words(&scratch.member_words),
        };
        let out_slice = UnsafeSlice::new(&mut out);
        parallel_for(n, |d| {
            let d = d as VertexId;
            if !cond(d) {
                return;
            }
            for &s in g_in.neighbors(d) {
                if probe.contains(s) && update(s, d) {
                    // SAFETY: each d written by exactly one task.
                    unsafe { out_slice.write(d as usize, true) };
                    // Ligra's early exit: once the destination is updated
                    // and cond would flip, stop scanning. We
                    // conservatively re-check cond.
                    if !cond(d) {
                        break;
                    }
                }
            }
        });
    }
    // 3. Restore the probe invariant (touched-only where the positions
    //    are known from the sparse id list).
    match frontier {
        VertexSubset::Sparse { ids, .. } => {
            if want_words {
                for &v in ids {
                    scratch.member_words[v as usize / 64] = 0;
                }
            } else {
                for &v in ids {
                    scratch.member_flags[v as usize] = false;
                }
            }
        }
        VertexSubset::Dense { .. } if want_words => scratch.member_words.fill(0),
        VertexSubset::Bits { .. } if !want_words => {
            frontier.for_each(|v| scratch.member_flags[v as usize] = false);
        }
        _ => {}
    }
    // 4. Package the result, counting members along the way so the next
    //    level's emptiness/size checks are O(1).
    if want_words {
        let mut words = scratch.take_words();
        let mut count = 0usize;
        for (v, b) in out.iter_mut().enumerate() {
            if *b {
                words[v / 64] |= 1u64 << (v % 64);
                count += 1;
                *b = false;
            }
        }
        scratch.put_flags_cleared(out);
        VertexSubset::from_words_counted(n, words, count)
    } else {
        let count = out.iter().filter(|&&b| b).count();
        VertexSubset::from_flags_counted(out, count)
    }
}

/// Apply `f(v)` to every member of `frontier`; keep vertices where `f`
/// returns true. Allocation-free at steady state, like `edge_map`: the
/// id materialization and the parallel keep/drop votes both come from
/// pooled `scratch` buffers (`vertex_map` sits on the per-level hot path
/// once concurrent jobs share a process, so a per-call `Vec<AtomicBool>`
/// would reintroduce exactly the churn the scratch engine removed).
pub fn vertex_map<F>(frontier: &VertexSubset, scratch: &mut EngineScratch, f: F) -> VertexSubset
where
    F: Fn(VertexId) -> bool + Sync,
{
    let n = frontier.n();
    // Materialize the frontier into a pooled id buffer by hand —
    // `with_frontier_ids` holds `&mut scratch`, which would lock out the
    // vote-slot access below.
    let mut ids = scratch.take_ids();
    match frontier.as_sparse_ids() {
        Some(s) => ids.extend_from_slice(s),
        None => frontier.for_each(|v| ids.push(v)),
    }
    // Vote in parallel into push_slots (contents are dead between engine
    // calls by contract; high-water length, so this is allocation-free
    // once warm). Disjoint indices — the standard UnsafeSlice pattern.
    if scratch.push_slots.len() < ids.len() {
        scratch.push_slots.resize(ids.len(), 0);
    }
    {
        let slots = crate::parallel::UnsafeSlice::new(&mut scratch.push_slots);
        let ids = &ids;
        // SAFETY: each loop index i writes only slot i, and
        // i < ids.len() ≤ push_slots.len() after the resize above.
        parallel_for(ids.len(), |i| unsafe {
            slots.write(i, f(ids[i]) as u32);
        });
    }
    let mut kept = scratch.take_ids();
    for (i, &v) in ids.iter().enumerate() {
        if scratch.push_slots[i] != 0 {
            kept.push(v);
        }
    }
    scratch.put_ids(ids);
    VertexSubset::from_ids(n, kept)
}
// audit: hot-path-end

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use std::sync::atomic::AtomicU32;

    fn line_graph(n: usize) -> (Csr, Csr) {
        let edges: Vec<_> = (0..n as u32 - 1).map(|i| (i, i + 1)).collect();
        let g = Csr::from_edges(n, &edges);
        let t = g.transpose();
        (g, t)
    }

    #[test]
    fn bfs_on_line_graph_push() {
        let (g, t) = line_graph(50);
        let parent: Vec<AtomicU32> = (0..50).map(|_| AtomicU32::new(u32::MAX)).collect();
        // audit: relaxed-ok — single-threaded setup before the traversal.
        parent[0].store(0, Ordering::Relaxed);
        let mut scratch = EngineScratch::new(50);
        let mut frontier = VertexSubset::single(50, 0);
        let mut depth = 0;
        while !frontier.is_empty() {
            let next = edge_map(
                &g,
                &t,
                &frontier,
                |s, d| {
                    parent[d as usize]
                        .compare_exchange(u32::MAX, s, Ordering::Relaxed, Ordering::Relaxed)
                        .is_ok()
                },
                |d| parent[d as usize].load(Ordering::Relaxed) == u32::MAX,
                EdgeMapOpts::default(),
                &mut scratch,
            );
            scratch.recycle(std::mem::replace(&mut frontier, next));
            depth += 1;
            assert!(depth <= 50);
        }
        assert_eq!(depth, 50 - 1 + 1); // reaches the end
        for v in 1..50 {
            assert_eq!(parent[v].load(Ordering::Relaxed), v as u32 - 1);
        }
    }

    #[test]
    fn push_and_pull_agree() {
        let (n, edges) = generators::rmat(9, 8, generators::RmatParams::graph500(), 8);
        let g = Csr::from_edges(n, &edges);
        let t = g.transpose();
        // One BFS step from a mid-degree frontier, forced both ways.
        let seed: Vec<VertexId> = (0..32).map(|i| (i * 7) as VertexId % n as VertexId).collect();
        let frontier = VertexSubset::from_ids(n, seed);
        let run = |den: u64| {
            let visited: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
            let mut scratch = EngineScratch::new(n);
            let next = edge_map(
                &g,
                &t,
                &frontier,
                |_s, d| !visited[d as usize].swap(true, Ordering::Relaxed),
                |_| true,
                EdgeMapOpts {
                    threshold_den: den,
                    bitvector_frontier: false,
                },
                &mut scratch,
            );
            let mut ids = next.ids();
            scratch.recycle(next);
            ids.sort_unstable();
            ids
        };
        // dense iff work > |E|/den: den=u64::MAX collapses the threshold
        // to 0 (always pull); den=1 raises it to |E| (always push).
        let pull = run(u64::MAX);
        let push = run(1);
        assert_eq!(push, pull);
    }

    #[test]
    fn bitvector_frontier_equivalent() {
        let (n, edges) = generators::rmat(9, 8, generators::RmatParams::graph500(), 9);
        let g = Csr::from_edges(n, &edges);
        let t = g.transpose();
        let frontier = VertexSubset::full(n);
        for bitvec in [false, true] {
            let mut scratch = EngineScratch::new(n);
            let next = edge_map(
                &g,
                &t,
                &frontier,
                |_s, _d| true,
                |_| true,
                EdgeMapOpts {
                    threshold_den: 1,
                    bitvector_frontier: bitvec,
                },
                &mut scratch,
            );
            // Every vertex with an in-edge is in the next frontier.
            let indeg = g.in_degrees();
            let expect: Vec<VertexId> = (0..n)
                .filter(|&v| indeg[v] > 0)
                .map(|v| v as VertexId)
                .collect();
            assert_eq!(next.count(), expect.len(), "cached count, bitvec={bitvec}");
            let mut got = next.ids();
            got.sort_unstable();
            assert_eq!(got, expect, "bitvec={bitvec}");
        }
    }

    /// All four (input representation × mode) corners produce the same
    /// frontier, exercising the borrow-vs-populate probe paths and the
    /// dense-input push materialization.
    #[test]
    fn representation_mode_corners_agree() {
        let (n, edges) = generators::rmat(9, 8, generators::RmatParams::graph500(), 21);
        let g = Csr::from_edges(n, &edges);
        let t = g.transpose();
        let seed: Vec<VertexId> = (0..48).map(|i| (i * 11) as VertexId % n as VertexId).collect();
        let mut dedup = seed.clone();
        dedup.sort_unstable();
        dedup.dedup();
        let sparse = VertexSubset::from_ids(n, dedup);
        let run = |f: &VertexSubset, den: u64, bitvec: bool| {
            let visited: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
            let mut scratch = EngineScratch::new(n);
            let next = edge_map(
                &g,
                &t,
                f,
                |_s, d| !visited[d as usize].swap(true, Ordering::Relaxed),
                |_| true,
                EdgeMapOpts {
                    threshold_den: den,
                    bitvector_frontier: bitvec,
                },
                &mut scratch,
            );
            let mut ids = next.ids();
            // Recycling must leave the scratch clean (poison asserts it).
            scratch.recycle(next);
            scratch.poison(7);
            ids.sort_unstable();
            ids
        };
        let want = run(&sparse, u64::MAX, false); // sparse input, pull mode
        for f in [sparse.clone(), sparse.to_dense(), sparse.to_bits()] {
            for den in [u64::MAX, 1] {
                for bitvec in [false, true] {
                    assert_eq!(
                        run(&f, den, bitvec),
                        want,
                        "repr mismatch den={den} bitvec={bitvec}"
                    );
                }
            }
        }
    }

    /// Reusing one scratch across many calls — with garbage poured into
    /// the dead regions between calls — changes nothing.
    #[test]
    fn scratch_reuse_with_poisoning_is_identical() {
        let (g, t) = line_graph(64);
        let run_bfs = |scratch: &mut EngineScratch, poison: bool| {
            let parent: Vec<AtomicU32> = (0..64).map(|_| AtomicU32::new(u32::MAX)).collect();
            // audit: relaxed-ok — single-threaded setup before the traversal.
            parent[0].store(0, Ordering::Relaxed);
            let mut frontier = VertexSubset::single(64, 0);
            while !frontier.is_empty() {
                if poison {
                    scratch.poison(0x5EED);
                }
                let next = edge_map(
                    &g,
                    &t,
                    &frontier,
                    |s, d| {
                        parent[d as usize]
                            .compare_exchange(u32::MAX, s, Ordering::Relaxed, Ordering::Relaxed)
                            .is_ok()
                    },
                    |d| parent[d as usize].load(Ordering::Relaxed) == u32::MAX,
                    EdgeMapOpts::default(),
                    scratch,
                );
                scratch.recycle(std::mem::replace(&mut frontier, next));
            }
            scratch.recycle(frontier);
            parent
                .into_iter()
                .map(|a| a.into_inner())
                .collect::<Vec<_>>()
        };
        let mut fresh = EngineScratch::new(64);
        let want = run_bfs(&mut fresh, false);
        let mut reused = EngineScratch::new(64);
        for _ in 0..3 {
            assert_eq!(run_bfs(&mut reused, true), want);
        }
    }

    #[test]
    fn vertex_map_filters() {
        let f = VertexSubset::from_ids(10, vec![1, 2, 3, 4]);
        let mut scratch = EngineScratch::new(10);
        let out = vertex_map(&f, &mut scratch, |v| v % 2 == 0);
        let mut ids = out.ids();
        ids.sort_unstable();
        assert_eq!(ids, vec![2, 4]);
    }

    #[test]
    fn vertex_map_reuses_scratch_without_allocating_ids() {
        // Dense input exercises the for_each materialization; repeated
        // calls must recycle the pooled buffers (returned subsets go back
        // via recycle, votes live in push_slots at high-water length).
        let mut scratch = EngineScratch::new(128);
        scratch.poison(1);
        for round in 0..4u32 {
            let f = VertexSubset::full(128).to_dense();
            let out = vertex_map(&f, &mut scratch, |v| v % 3 == round % 3);
            let want = (0..128u32).filter(|v| v % 3 == round % 3).count();
            assert_eq!(out.count(), want);
            scratch.recycle(out);
            scratch.poison(round as u64 + 2);
        }
    }
}
