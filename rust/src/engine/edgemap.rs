//! Direction-switching EdgeMap and VertexMap (the Ligra API our framework
//! extends — the paper's BFS/BC numbers ride on "its innovative push and
//! pull switch optimization", §6.2).
//!
//! `edge_map` applies `update(src, dst) -> bool` over the edges leaving
//! the frontier, gated by `cond(dst)`; returns the new frontier (vertices
//! for which some update returned true).
//!
//! - **Push (sparse)**: iterate frontier vertices' out-edges; updates may
//!   race, so `update` must be CAS-style idempotent.
//! - **Pull (dense)**: iterate *all* destinations with `cond(dst)`,
//!   scanning in-edges for frontier members — no write races, and early
//!   exit once `cond` is satisfied.
//!
//! The switch uses Ligra's heuristic: pull when
//! `|frontier| + outEdges(frontier) > |E| / threshold_den`.

use super::frontier::VertexSubset;
use crate::graph::{Csr, VertexId};
use crate::parallel::{parallel_for, UnsafeSlice};
use std::sync::atomic::{AtomicBool, Ordering};

/// EdgeMap tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct EdgeMapOpts {
    /// Pull when frontier work exceeds |E| / threshold_den (Ligra uses 20).
    pub threshold_den: u64,
    /// Keep the output frontier as a bitvector (Tables 7/8's "Bitvector"
    /// optimization) instead of a dense bool vector.
    pub bitvector_frontier: bool,
}

impl Default for EdgeMapOpts {
    fn default() -> Self {
        EdgeMapOpts {
            threshold_den: 20,
            bitvector_frontier: false,
        }
    }
}

/// Apply `update` over edges out of `frontier`; `g` is the out-edge CSR
/// and `g_in` its transpose (used for pull mode). Returns the new
/// frontier.
pub fn edge_map<U, C>(
    g: &Csr,
    g_in: &Csr,
    frontier: &VertexSubset,
    update: U,
    cond: C,
    opts: EdgeMapOpts,
) -> VertexSubset
where
    U: Fn(VertexId, VertexId) -> bool + Sync,
    C: Fn(VertexId) -> bool + Sync,
{
    let m = g.num_edges() as u64;
    let frontier_ids = frontier.ids();
    let out_work: u64 = frontier_ids.iter().map(|&v| g.degree(v) as u64).sum();
    let dense = out_work + frontier_ids.len() as u64 > m / opts.threshold_den.max(1);
    if dense {
        edge_map_pull(g_in, frontier, update, cond, opts)
    } else {
        edge_map_push(g, &frontier_ids, update, cond)
    }
}

/// Push mode: parallel over frontier vertices, scattering updates.
fn edge_map_push<U, C>(g: &Csr, frontier_ids: &[VertexId], update: U, cond: C) -> VertexSubset
where
    U: Fn(VertexId, VertexId) -> bool + Sync,
    C: Fn(VertexId) -> bool + Sync,
{
    let n = g.num_vertices();
    let out_flags: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
    parallel_for(frontier_ids.len(), |i| {
        let s = frontier_ids[i];
        for &d in g.neighbors(s) {
            if cond(d) && update(s, d) {
                out_flags[d as usize].store(true, Ordering::Relaxed);
            }
        }
    });
    let ids: Vec<VertexId> = out_flags
        .iter()
        .enumerate()
        .filter_map(|(v, f)| f.load(Ordering::Relaxed).then_some(v as VertexId))
        .collect();
    VertexSubset::from_ids(n, ids)
}

/// Pull mode: parallel over all destinations satisfying `cond`, scanning
/// in-neighbors for frontier membership.
fn edge_map_pull<U, C>(
    g_in: &Csr,
    frontier: &VertexSubset,
    update: U,
    cond: C,
    opts: EdgeMapOpts,
) -> VertexSubset
where
    U: Fn(VertexId, VertexId) -> bool + Sync,
    C: Fn(VertexId) -> bool + Sync,
{
    let n = g_in.num_vertices();
    // Membership structure: bitvector (compact, the §6.3 optimization) or
    // dense bools.
    let member = if opts.bitvector_frontier {
        frontier.to_bits()
    } else {
        frontier.to_dense()
    };
    let mut out = vec![false; n];
    let out_slice = UnsafeSlice::new(&mut out);
    parallel_for(n, |d| {
        let d = d as VertexId;
        if !cond(d) {
            return;
        }
        for &s in g_in.neighbors(d) {
            if member.contains(s) && update(s, d) {
                // Safety: each d written by exactly one task.
                unsafe { out_slice.write(d as usize, true) };
                // Ligra's early exit: once the destination is updated and
                // cond would flip, stop scanning. We conservatively
                // re-check cond.
                if !cond(d) {
                    break;
                }
            }
        }
    });
    if opts.bitvector_frontier {
        VertexSubset::from_flags(out).to_bits()
    } else {
        VertexSubset::from_flags(out)
    }
}

/// Apply `f(v)` to every member of `frontier`; keep vertices where `f`
/// returns true.
pub fn vertex_map<F>(frontier: &VertexSubset, f: F) -> VertexSubset
where
    F: Fn(VertexId) -> bool + Sync,
{
    let ids = frontier.ids();
    let keep: Vec<AtomicBool> = (0..ids.len()).map(|_| AtomicBool::new(false)).collect();
    parallel_for(ids.len(), |i| {
        if f(ids[i]) {
            keep[i].store(true, Ordering::Relaxed);
        }
    });
    let new_ids = ids
        .iter()
        .zip(&keep)
        .filter_map(|(&v, k)| k.load(Ordering::Relaxed).then_some(v))
        .collect();
    VertexSubset::from_ids(frontier.n(), new_ids)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use std::sync::atomic::AtomicU32;

    fn line_graph(n: usize) -> (Csr, Csr) {
        let edges: Vec<_> = (0..n as u32 - 1).map(|i| (i, i + 1)).collect();
        let g = Csr::from_edges(n, &edges);
        let t = g.transpose();
        (g, t)
    }

    #[test]
    fn bfs_on_line_graph_push() {
        let (g, t) = line_graph(50);
        let parent: Vec<AtomicU32> = (0..50).map(|_| AtomicU32::new(u32::MAX)).collect();
        parent[0].store(0, Ordering::Relaxed);
        let mut frontier = VertexSubset::single(50, 0);
        let mut depth = 0;
        while !frontier.is_empty() {
            frontier = edge_map(
                &g,
                &t,
                &frontier,
                |s, d| {
                    parent[d as usize]
                        .compare_exchange(u32::MAX, s, Ordering::Relaxed, Ordering::Relaxed)
                        .is_ok()
                },
                |d| parent[d as usize].load(Ordering::Relaxed) == u32::MAX,
                EdgeMapOpts::default(),
            );
            depth += 1;
            assert!(depth <= 50);
        }
        assert_eq!(depth, 50 - 1 + 1); // reaches the end
        for v in 1..50 {
            assert_eq!(parent[v].load(Ordering::Relaxed), v as u32 - 1);
        }
    }

    #[test]
    fn push_and_pull_agree() {
        let (n, edges) = generators::rmat(9, 8, generators::RmatParams::graph500(), 8);
        let g = Csr::from_edges(n, &edges);
        let t = g.transpose();
        // One BFS step from a mid-degree frontier, forced both ways.
        let seed: Vec<VertexId> = (0..32).map(|i| (i * 7) as VertexId % n as VertexId).collect();
        let frontier = VertexSubset::from_ids(n, seed);
        let run = |den: u64| {
            let visited: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
            let next = edge_map(
                &g,
                &t,
                &frontier,
                |_s, d| {
                    !visited[d as usize].swap(true, Ordering::Relaxed)
                },
                |_| true,
                EdgeMapOpts {
                    threshold_den: den,
                    bitvector_frontier: false,
                },
            );
            let mut ids = next.ids();
            ids.sort_unstable();
            ids
        };
        let push = run(u64::MAX); // threshold huge => push
        let pull = run(1); // => pull
        assert_eq!(push, pull);
    }

    #[test]
    fn bitvector_frontier_equivalent() {
        let (n, edges) = generators::rmat(9, 8, generators::RmatParams::graph500(), 9);
        let g = Csr::from_edges(n, &edges);
        let t = g.transpose();
        let frontier = VertexSubset::full(n);
        for bitvec in [false, true] {
            let next = edge_map(
                &g,
                &t,
                &frontier,
                |_s, _d| true,
                |_| true,
                EdgeMapOpts {
                    threshold_den: 1,
                    bitvector_frontier: bitvec,
                },
            );
            // Every vertex with an in-edge is in the next frontier.
            let indeg = g.in_degrees();
            let expect: Vec<VertexId> = (0..n)
                .filter(|&v| indeg[v] > 0)
                .map(|v| v as VertexId)
                .collect();
            let mut got = next.ids();
            got.sort_unstable();
            assert_eq!(got, expect, "bitvec={bitvec}");
        }
    }

    #[test]
    fn vertex_map_filters() {
        let f = VertexSubset::from_ids(10, vec![1, 2, 3, 4]);
        let out = vertex_map(&f, |v| v % 2 == 0);
        let mut ids = out.ids();
        ids.sort_unstable();
        assert_eq!(ids, vec![2, 4]);
    }
}
