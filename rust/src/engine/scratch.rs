//! Reusable execution scratch for the frontier engine.
//!
//! The paper's whole argument is that iteration state should stay
//! cache-resident while the edge structure streams from DRAM — yet a
//! naive `edge_map` re-allocates and zero-fills O(n) output flags on
//! *every* level, churning pages and evicting exactly the state §4 works
//! to keep hot. [`EngineScratch`] makes the steady state allocation-free:
//! each frontier app's `Prepared` state owns one instance and threads it
//! through every [`super::edge_map`] call.
//!
//! Two disciplines keep reuse cheap **and** safe:
//!
//! - **Invariant buffers** (`out_flags`, `member_flags`, `member_words`,
//!   and everything sitting in the flag/word pools) are all-clear between
//!   calls. `edge_map` restores the invariant with **touched-only
//!   clearing**: after push mode it resets exactly the flags named by the
//!   new frontier's id list; after pull mode with a sparse input it
//!   resets exactly the membership slots that input's ids set. The
//!   invariant is asserted (not silently re-established) by
//!   [`EngineScratch::poison`], so a missed clear fails loudly in tests.
//! - **Dead buffers** (pooled id vectors' spare capacity, the cost
//!   prefix) carry no information between calls; every use fully rewrites
//!   what it reads. [`EngineScratch::poison`] fills them with garbage so
//!   the scratch-parity tests prove nothing leaks through them.
//!
//! Ownership contract (see also rust/README.md "Engine scratch & memory
//! discipline"): the **app** owns the scratch; `edge_map` borrows it per
//! call; frontiers returned by `edge_map` draw their storage from the
//! scratch's pools and must eventually be handed back via
//! [`EngineScratch::recycle`] to close the loop (dropping one instead
//! merely costs a fresh allocation later — never correctness).

use super::frontier::VertexSubset;
use crate::graph::VertexId;
use std::sync::atomic::{AtomicBool, Ordering};

/// Reusable buffers for [`super::edge_map`]: double-buffered frontier
/// flag arrays with touched-only clearing, pooled sparse-id vectors, and
/// the out-degree prefix used by cost-balanced push mode.
#[derive(Debug)]
pub struct EngineScratch {
    n: usize,
    /// Push-mode "already in the next frontier" flags. Invariant: all
    /// `false` between `edge_map` calls (touched-only cleared via the new
    /// frontier's id list).
    pub(super) out_flags: Vec<AtomicBool>,
    /// Pull-mode membership probe, dense-byte form. Invariant: all
    /// `false` between calls.
    pub(super) member_flags: Vec<bool>,
    /// Pull-mode membership probe, packed-bit form (the §6.3 bitvector
    /// optimization). Invariant: all zero between calls.
    pub(super) member_words: Vec<u64>,
    /// Out-degree prefix over the current frontier for cost-balanced push
    /// (rebuilt from scratch every push; contents dead between calls).
    pub(super) cost_prefix: Vec<u64>,
    /// Push-mode winner slots, kept at high-water length so no per-call
    /// zero-fill is ever needed: only `cursor` slots are written and read
    /// each call, everything beyond is dead garbage.
    pub(super) push_slots: Vec<VertexId>,
    /// Recycled sparse-id vectors (len 0; capacity retained).
    id_pool: Vec<Vec<VertexId>>,
    /// Recycled dense flag vectors (len n, all false).
    flag_pool: Vec<Vec<bool>>,
    /// Recycled bit-word vectors (len ⌈n/64⌉, all zero).
    word_pool: Vec<Vec<u64>>,
    /// High-water mark of bytes held across the run (for `Metrics`).
    peak_bytes: usize,
}

impl EngineScratch {
    /// Scratch for graphs of `n` vertices. The fixed O(n) probe/flag
    /// arrays are allocated eagerly; pooled buffers grow on demand during
    /// the first traversal and are reused from then on.
    pub fn new(n: usize) -> EngineScratch {
        let words = n.div_ceil(64);
        let mut s = EngineScratch {
            n,
            out_flags: (0..n).map(|_| AtomicBool::new(false)).collect(),
            member_flags: vec![false; n],
            member_words: vec![0; words],
            cost_prefix: Vec::new(),
            push_slots: Vec::new(),
            id_pool: Vec::new(),
            flag_pool: Vec::new(),
            word_pool: Vec::new(),
            peak_bytes: 0,
        };
        s.update_peak();
        s
    }

    /// Universe size this scratch was built for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Take a cleared id vector from the pool (or a fresh empty one).
    pub fn take_ids(&mut self) -> Vec<VertexId> {
        self.id_pool.pop().unwrap_or_default()
    }

    /// Return an id vector to the pool (its contents are dead).
    pub fn put_ids(&mut self, mut v: Vec<VertexId>) {
        v.clear();
        self.id_pool.push(v);
        self.update_peak();
    }

    /// Take an all-false flag vector of len `n` from the pool.
    pub(super) fn take_flags(&mut self) -> Vec<bool> {
        self.flag_pool.pop().unwrap_or_else(|| vec![false; self.n])
    }

    /// Return a flag vector the caller has already restored to all-false
    /// (touched-only). Debug builds verify the contract.
    pub(super) fn put_flags_cleared(&mut self, v: Vec<bool>) {
        debug_assert!(v.iter().all(|&b| !b), "flag buffer returned dirty");
        debug_assert_eq!(v.len(), self.n);
        self.flag_pool.push(v);
        self.update_peak();
    }

    /// Take an all-zero word vector of len ⌈n/64⌉ from the pool.
    pub(super) fn take_words(&mut self) -> Vec<u64> {
        self.word_pool
            .pop()
            .unwrap_or_else(|| vec![0; self.n.div_ceil(64)])
    }

    /// Run `f` over the frontier's members as a contiguous id slice
    /// without allocating: borrows sparse storage directly, otherwise
    /// materializes into a pooled vector that returns to the pool
    /// afterwards. The one place the borrow-or-materialize pool
    /// discipline lives (BC's backward sweep and friends).
    pub fn with_frontier_ids<R>(
        &mut self,
        frontier: &VertexSubset,
        f: impl FnOnce(&[VertexId]) -> R,
    ) -> R {
        match frontier.as_sparse_ids() {
            Some(ids) => f(ids),
            None => {
                let mut ids = self.take_ids();
                frontier.for_each(|v| ids.push(v));
                let r = f(&ids);
                self.put_ids(ids);
                r
            }
        }
    }

    /// Recycle a frontier, returning its storage to the pools. Sparse
    /// storage is reused as-is (contents dead beyond len 0); dense/bit
    /// storage is restored to the all-clear pool invariant first.
    pub fn recycle(&mut self, f: VertexSubset) {
        match f {
            VertexSubset::Sparse { ids, .. } => self.put_ids(ids),
            VertexSubset::Dense { mut flags, count } => {
                // No id list to clear by, so this one is a memset — but
                // only of a buffer a pull pass (itself O(n)) produced.
                if count != Some(0) {
                    flags.fill(false);
                }
                if flags.len() == self.n {
                    self.flag_pool.push(flags);
                }
            }
            VertexSubset::Bits { mut words, count, .. } => {
                if count != Some(0) {
                    words.fill(0);
                }
                if words.len() == self.n.div_ceil(64) {
                    self.word_pool.push(words);
                }
            }
        }
        self.update_peak();
    }

    /// Bytes currently held by the scratch (checked-out frontiers are
    /// counted when they come back through [`EngineScratch::recycle`]).
    pub fn bytes(&self) -> usize {
        self.out_flags.len()
            + self.member_flags.len()
            + self.member_words.len() * 8
            + self.cost_prefix.capacity() * 8
            + self.push_slots.capacity() * 4
            + self.id_pool.iter().map(|v| v.capacity() * 4).sum::<usize>()
            + self.flag_pool.iter().map(|v| v.len()).sum::<usize>()
            + self.word_pool.iter().map(|v| v.len() * 8).sum::<usize>()
    }

    /// High-water mark of [`EngineScratch::bytes`] over the scratch's
    /// lifetime — what `Metrics` reports as the preallocation cost.
    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    fn update_peak(&mut self) {
        self.peak_bytes = self.peak_bytes.max(self.bytes());
    }

    /// Test hook: assert the all-clear invariants hold, then fill every
    /// *dead* region (pooled id storage, the cost prefix) with garbage
    /// derived from `seed`. Reused-scratch results must be bitwise
    /// identical to fresh-allocation results no matter what this writes —
    /// and a missed touched-only clear trips the assertions here instead
    /// of silently corrupting a later traversal.
    pub fn poison(&mut self, seed: u64) {
        assert!(
            self.out_flags.iter().all(|f| !f.load(Ordering::Relaxed)),
            "scratch invariant violated: out_flags not cleared"
        );
        assert!(
            self.member_flags.iter().all(|&b| !b),
            "scratch invariant violated: member_flags not cleared"
        );
        assert!(
            self.member_words.iter().all(|&w| w == 0),
            "scratch invariant violated: member_words not cleared"
        );
        for v in &self.flag_pool {
            assert!(v.iter().all(|&b| !b), "pooled flag buffer dirty");
        }
        for v in &self.word_pool {
            assert!(v.iter().all(|&w| w == 0), "pooled word buffer dirty");
        }
        // Garbage the dead regions without changing capacities: resize up
        // to capacity writing junk, then truncate back to empty.
        let junk_id = (seed as u32) | 1;
        for v in &mut self.id_pool {
            let cap = v.capacity();
            v.resize(cap, junk_id);
            v.clear();
        }
        self.push_slots.fill(junk_id);
        let cap = self.cost_prefix.capacity();
        self.cost_prefix.clear();
        self.cost_prefix.resize(cap, seed | 1);
        self.cost_prefix.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pools_recycle_storage() {
        let mut s = EngineScratch::new(100);
        let mut ids = s.take_ids();
        ids.extend([1u32, 2, 3]);
        let cap = ids.capacity();
        s.put_ids(ids);
        let back = s.take_ids();
        assert!(back.is_empty());
        assert!(back.capacity() >= cap.min(3));
    }

    #[test]
    fn recycle_restores_invariants() {
        let mut s = EngineScratch::new(128);
        s.recycle(VertexSubset::from_flags({
            let mut f = vec![false; 128];
            f[3] = true;
            f
        }));
        s.recycle(VertexSubset::from_ids(128, vec![5, 9]).to_bits());
        // Poison asserts the pools are clean.
        s.poison(0xDEAD_BEEF);
        let f = s.take_flags();
        assert!(f.iter().all(|&b| !b));
        let w = s.take_words();
        assert!(w.iter().all(|&x| x == 0));
    }

    #[test]
    fn peak_bytes_grows_monotonically() {
        let mut s = EngineScratch::new(64);
        let base = s.peak_bytes();
        assert!(base > 0);
        let mut ids = s.take_ids();
        ids.extend(0..64u32);
        s.put_ids(ids);
        assert!(s.peak_bytes() >= base);
        assert!(s.peak_bytes() >= s.bytes());
    }

    #[test]
    #[should_panic(expected = "out_flags not cleared")]
    fn poison_catches_dirty_flags() {
        let mut s = EngineScratch::new(16);
        // audit: relaxed-ok — single-threaded test setup.
        s.out_flags[4].store(true, Ordering::Relaxed);
        s.poison(1);
    }
}
