//! Vertex subsets (frontiers) with three physical representations:
//! sparse id list, dense boolean vector, and packed **bitvector** — the
//! cache optimization "many frameworks adopt" that §6.3 compares against
//! vertex reordering (Tables 7/8 "Bitvector" rows).

use crate::graph::VertexId;

/// A subset of vertices. Representation is switched explicitly by the
/// engine based on density; all representations answer membership.
#[derive(Debug, Clone)]
pub enum VertexSubset {
    /// Unsorted list of member ids.
    Sparse { n: usize, ids: Vec<VertexId> },
    /// One bool per vertex.
    Dense { flags: Vec<bool> },
    /// One bit per vertex (64 per word) — the cache-compact form.
    Bits { n: usize, words: Vec<u64> },
}

impl VertexSubset {
    /// Empty subset over `n` vertices (sparse).
    pub fn empty(n: usize) -> VertexSubset {
        VertexSubset::Sparse { n, ids: Vec::new() }
    }

    /// Singleton subset.
    pub fn single(n: usize, v: VertexId) -> VertexSubset {
        VertexSubset::Sparse { n, ids: vec![v] }
    }

    /// Full subset (dense).
    pub fn full(n: usize) -> VertexSubset {
        VertexSubset::Dense {
            flags: vec![true; n],
        }
    }

    pub fn from_ids(n: usize, ids: Vec<VertexId>) -> VertexSubset {
        debug_assert!(ids.iter().all(|&v| (v as usize) < n));
        VertexSubset::Sparse { n, ids }
    }

    pub fn from_flags(flags: Vec<bool>) -> VertexSubset {
        VertexSubset::Dense { flags }
    }

    /// Universe size.
    pub fn n(&self) -> usize {
        match self {
            VertexSubset::Sparse { n, .. } | VertexSubset::Bits { n, .. } => *n,
            VertexSubset::Dense { flags } => flags.len(),
        }
    }

    /// Number of members.
    pub fn count(&self) -> usize {
        match self {
            VertexSubset::Sparse { ids, .. } => ids.len(),
            VertexSubset::Dense { flags } => flags.iter().filter(|&&b| b).count(),
            VertexSubset::Bits { words, .. } => {
                words.iter().map(|w| w.count_ones() as usize).sum()
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        match self {
            VertexSubset::Sparse { ids, .. } => ids.is_empty(),
            _ => self.count() == 0,
        }
    }

    /// Membership test.
    #[inline]
    pub fn contains(&self, v: VertexId) -> bool {
        match self {
            VertexSubset::Sparse { ids, .. } => ids.contains(&v),
            VertexSubset::Dense { flags } => flags[v as usize],
            VertexSubset::Bits { words, .. } => {
                (words[v as usize / 64] >> (v as usize % 64)) & 1 == 1
            }
        }
    }

    /// Member ids (materializes for dense forms, ascending).
    pub fn ids(&self) -> Vec<VertexId> {
        match self {
            VertexSubset::Sparse { ids, .. } => ids.clone(),
            VertexSubset::Dense { flags } => flags
                .iter()
                .enumerate()
                .filter_map(|(i, &b)| b.then_some(i as VertexId))
                .collect(),
            VertexSubset::Bits { n, words } => {
                let mut out = Vec::new();
                for (wi, &w) in words.iter().enumerate() {
                    let mut bits = w;
                    while bits != 0 {
                        let b = bits.trailing_zeros() as usize;
                        let v = wi * 64 + b;
                        if v < *n {
                            out.push(v as VertexId);
                        }
                        bits &= bits - 1;
                    }
                }
                out
            }
        }
    }

    /// Convert to the dense boolean form.
    pub fn to_dense(&self) -> VertexSubset {
        match self {
            VertexSubset::Dense { .. } => self.clone(),
            _ => {
                let mut flags = vec![false; self.n()];
                for v in self.ids() {
                    flags[v as usize] = true;
                }
                VertexSubset::Dense { flags }
            }
        }
    }

    /// Convert to the packed bitvector form.
    pub fn to_bits(&self) -> VertexSubset {
        match self {
            VertexSubset::Bits { .. } => self.clone(),
            _ => {
                let n = self.n();
                let mut words = vec![0u64; n.div_ceil(64)];
                for v in self.ids() {
                    words[v as usize / 64] |= 1u64 << (v as usize % 64);
                }
                VertexSubset::Bits { n, words }
            }
        }
    }

    /// Convert to sparse form.
    pub fn to_sparse(&self) -> VertexSubset {
        match self {
            VertexSubset::Sparse { .. } => self.clone(),
            _ => VertexSubset::Sparse {
                n: self.n(),
                ids: self.ids(),
            },
        }
    }

    /// Bytes the representation occupies (for working-set metrics).
    pub fn bytes(&self) -> usize {
        match self {
            VertexSubset::Sparse { ids, .. } => ids.len() * 4,
            VertexSubset::Dense { flags } => flags.len(),
            VertexSubset::Bits { words, .. } => words.len() * 8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn representations_agree() {
        let s = VertexSubset::from_ids(200, vec![3, 64, 65, 199]);
        let d = s.to_dense();
        let b = s.to_bits();
        for v in 0..200u32 {
            let m = s.contains(v);
            assert_eq!(d.contains(v), m, "dense v={v}");
            assert_eq!(b.contains(v), m, "bits v={v}");
        }
        assert_eq!(s.count(), 4);
        assert_eq!(d.count(), 4);
        assert_eq!(b.count(), 4);
        let mut ids = b.ids();
        ids.sort_unstable();
        assert_eq!(ids, vec![3, 64, 65, 199]);
    }

    #[test]
    fn empty_and_full() {
        let e = VertexSubset::empty(10);
        assert!(e.is_empty());
        assert_eq!(e.count(), 0);
        let f = VertexSubset::full(10);
        assert_eq!(f.count(), 10);
        assert!(f.contains(9));
    }

    #[test]
    fn bits_compact() {
        let f = VertexSubset::full(1 << 16).to_bits();
        assert_eq!(f.bytes(), (1 << 16) / 8);
        assert_eq!(f.count(), 1 << 16);
    }

    #[test]
    fn prop_roundtrip_conversions() {
        check("frontier conversions preserve membership", 25, |g| {
            let n = g.usize(1..300);
            let mut ids: Vec<u32> = (0..g.usize(0..n)).map(|_| g.u32(0..n as u32)).collect();
            ids.sort_unstable();
            ids.dedup();
            let s = VertexSubset::from_ids(n, ids.clone());
            let back = s.to_bits().to_dense().to_sparse();
            let mut bids = back.ids();
            bids.sort_unstable();
            assert_eq!(bids, ids);
        });
    }
}
