//! Vertex subsets (frontiers) with three physical representations:
//! sparse id list, dense boolean vector, and packed **bitvector** — the
//! cache optimization "many frameworks adopt" that §6.3 compares against
//! vertex reordering (Tables 7/8 "Bitvector" rows).
//!
//! Dense forms carry an optional **cached member count**: the engine
//! always knows the count when it builds a frontier (push mode counts
//! winners at the cursor, pull mode counts during the bit-pack scan), so
//! `count()`/`is_empty()` on engine-produced frontiers are O(1) instead
//! of an O(n) rescan per level.

use crate::graph::VertexId;

/// A subset of vertices. Representation is switched explicitly by the
/// engine based on density; all representations answer membership.
#[derive(Debug, Clone)]
pub enum VertexSubset {
    /// Unsorted list of member ids.
    Sparse { n: usize, ids: Vec<VertexId> },
    /// One bool per vertex, plus the member count when the producer
    /// already knew it.
    Dense {
        flags: Vec<bool>,
        count: Option<usize>,
    },
    /// One bit per vertex (64 per word) — the cache-compact form.
    Bits {
        n: usize,
        words: Vec<u64>,
        count: Option<usize>,
    },
}

impl VertexSubset {
    /// Empty subset over `n` vertices (sparse).
    pub fn empty(n: usize) -> VertexSubset {
        VertexSubset::Sparse { n, ids: Vec::new() }
    }

    /// Singleton subset.
    pub fn single(n: usize, v: VertexId) -> VertexSubset {
        VertexSubset::Sparse { n, ids: vec![v] }
    }

    /// Full subset (dense).
    pub fn full(n: usize) -> VertexSubset {
        VertexSubset::Dense {
            flags: vec![true; n],
            count: Some(n),
        }
    }

    pub fn from_ids(n: usize, ids: Vec<VertexId>) -> VertexSubset {
        debug_assert!(ids.iter().all(|&v| (v as usize) < n));
        VertexSubset::Sparse { n, ids }
    }

    pub fn from_flags(flags: Vec<bool>) -> VertexSubset {
        VertexSubset::Dense { flags, count: None }
    }

    /// Dense subset whose member count the caller already knows (the
    /// engine's O(1) `count`/`is_empty` fast path).
    pub fn from_flags_counted(flags: Vec<bool>, count: usize) -> VertexSubset {
        debug_assert_eq!(count, flags.iter().filter(|&&b| b).count());
        VertexSubset::Dense {
            flags,
            count: Some(count),
        }
    }

    /// Bitvector subset with a known member count.
    pub fn from_words_counted(n: usize, words: Vec<u64>, count: usize) -> VertexSubset {
        debug_assert_eq!(words.len(), n.div_ceil(64));
        debug_assert_eq!(
            count,
            words.iter().map(|w| w.count_ones() as usize).sum::<usize>()
        );
        VertexSubset::Bits {
            n,
            words,
            count: Some(count),
        }
    }

    /// Universe size.
    pub fn n(&self) -> usize {
        match self {
            VertexSubset::Sparse { n, .. } | VertexSubset::Bits { n, .. } => *n,
            VertexSubset::Dense { flags, .. } => flags.len(),
        }
    }

    /// Number of members (O(1) for sparse and counted-dense forms).
    pub fn count(&self) -> usize {
        match self {
            VertexSubset::Sparse { ids, .. } => ids.len(),
            VertexSubset::Dense { flags, count } => {
                count.unwrap_or_else(|| flags.iter().filter(|&&b| b).count())
            }
            VertexSubset::Bits { words, count, .. } => {
                count.unwrap_or_else(|| words.iter().map(|w| w.count_ones() as usize).sum())
            }
        }
    }

    /// Emptiness check: O(1) with a cached count, otherwise it
    /// short-circuits on the first set flag/word instead of counting the
    /// whole array (the common case — a nonempty frontier — answers
    /// after a handful of elements).
    pub fn is_empty(&self) -> bool {
        match self {
            VertexSubset::Sparse { ids, .. } => ids.is_empty(),
            VertexSubset::Dense { flags, count } => match count {
                Some(c) => *c == 0,
                None => !flags.contains(&true),
            },
            VertexSubset::Bits { words, count, .. } => match count {
                Some(c) => *c == 0,
                None => words.iter().all(|&w| w == 0),
            },
        }
    }

    /// Membership test.
    #[inline]
    pub fn contains(&self, v: VertexId) -> bool {
        match self {
            VertexSubset::Sparse { ids, .. } => ids.contains(&v),
            VertexSubset::Dense { flags, .. } => flags[v as usize],
            VertexSubset::Bits { words, .. } => {
                (words[v as usize / 64] >> (v as usize % 64)) & 1 == 1
            }
        }
    }

    /// Borrow the id list when the subset is already sparse (the engine's
    /// allocation-free push path).
    pub fn as_sparse_ids(&self) -> Option<&[VertexId]> {
        match self {
            VertexSubset::Sparse { ids, .. } => Some(ids),
            _ => None,
        }
    }

    /// Visit every member without materializing an id list.
    pub fn for_each(&self, mut f: impl FnMut(VertexId)) {
        match self {
            VertexSubset::Sparse { ids, .. } => {
                for &v in ids {
                    f(v);
                }
            }
            VertexSubset::Dense { flags, .. } => {
                for (v, &b) in flags.iter().enumerate() {
                    if b {
                        f(v as VertexId);
                    }
                }
            }
            VertexSubset::Bits { n, words, .. } => {
                for (wi, &w) in words.iter().enumerate() {
                    let mut bits = w;
                    while bits != 0 {
                        let b = bits.trailing_zeros() as usize;
                        let v = wi * 64 + b;
                        if v < *n {
                            f(v as VertexId);
                        }
                        bits &= bits - 1;
                    }
                }
            }
        }
    }

    /// Member ids (materializes for dense forms, ascending).
    pub fn ids(&self) -> Vec<VertexId> {
        match self {
            VertexSubset::Sparse { ids, .. } => ids.clone(),
            _ => {
                let mut out = Vec::with_capacity(self.count());
                self.for_each(|v| out.push(v));
                out
            }
        }
    }

    /// Convert to the dense boolean form.
    pub fn to_dense(&self) -> VertexSubset {
        match self {
            VertexSubset::Dense { .. } => self.clone(),
            _ => {
                let mut flags = vec![false; self.n()];
                let mut count = 0;
                self.for_each(|v| {
                    flags[v as usize] = true;
                    count += 1;
                });
                VertexSubset::Dense {
                    flags,
                    count: Some(count),
                }
            }
        }
    }

    /// Convert to the packed bitvector form.
    pub fn to_bits(&self) -> VertexSubset {
        match self {
            VertexSubset::Bits { .. } => self.clone(),
            _ => {
                let n = self.n();
                let mut words = vec![0u64; n.div_ceil(64)];
                let mut count = 0;
                self.for_each(|v| {
                    words[v as usize / 64] |= 1u64 << (v as usize % 64);
                    count += 1;
                });
                VertexSubset::Bits {
                    n,
                    words,
                    count: Some(count),
                }
            }
        }
    }

    /// Convert to sparse form.
    pub fn to_sparse(&self) -> VertexSubset {
        match self {
            VertexSubset::Sparse { .. } => self.clone(),
            _ => VertexSubset::Sparse {
                n: self.n(),
                ids: self.ids(),
            },
        }
    }

    /// Bytes the representation occupies (for working-set metrics).
    pub fn bytes(&self) -> usize {
        match self {
            VertexSubset::Sparse { ids, .. } => ids.len() * 4,
            VertexSubset::Dense { flags, .. } => flags.len(),
            VertexSubset::Bits { words, .. } => words.len() * 8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn representations_agree() {
        let s = VertexSubset::from_ids(200, vec![3, 64, 65, 199]);
        let d = s.to_dense();
        let b = s.to_bits();
        for v in 0..200u32 {
            let m = s.contains(v);
            assert_eq!(d.contains(v), m, "dense v={v}");
            assert_eq!(b.contains(v), m, "bits v={v}");
        }
        assert_eq!(s.count(), 4);
        assert_eq!(d.count(), 4);
        assert_eq!(b.count(), 4);
        let mut ids = b.ids();
        ids.sort_unstable();
        assert_eq!(ids, vec![3, 64, 65, 199]);
    }

    #[test]
    fn empty_and_full() {
        let e = VertexSubset::empty(10);
        assert!(e.is_empty());
        assert_eq!(e.count(), 0);
        let f = VertexSubset::full(10);
        assert_eq!(f.count(), 10);
        assert!(f.contains(9));
    }

    #[test]
    fn bits_compact() {
        let f = VertexSubset::full(1 << 16).to_bits();
        assert_eq!(f.bytes(), (1 << 16) / 8);
        assert_eq!(f.count(), 1 << 16);
    }

    #[test]
    fn uncounted_dense_short_circuits_and_counts() {
        let mut flags = vec![false; 1000];
        flags[1] = true;
        let d = VertexSubset::from_flags(flags);
        assert!(!d.is_empty());
        assert_eq!(d.count(), 1);
        let e = VertexSubset::from_flags(vec![false; 1000]);
        assert!(e.is_empty());
        let w = VertexSubset::from_flags(vec![false; 1000]).to_bits();
        assert!(w.is_empty());
    }

    #[test]
    fn counted_constructors_report_o1() {
        let mut flags = vec![false; 130];
        flags[0] = true;
        flags[129] = true;
        let d = VertexSubset::from_flags_counted(flags, 2);
        assert_eq!(d.count(), 2);
        assert!(!d.is_empty());
        let mut words = vec![0u64; 3];
        words[0] = 0b101;
        let b = VertexSubset::from_words_counted(130, words, 2);
        assert_eq!(b.count(), 2);
        assert!(!b.is_empty());
        assert!(b.contains(0) && b.contains(2) && !b.contains(1));
    }

    #[test]
    fn for_each_matches_ids() {
        let s = VertexSubset::from_ids(200, vec![0, 63, 64, 127, 199]);
        for form in [s.clone(), s.to_dense(), s.to_bits()] {
            let mut seen = Vec::new();
            form.for_each(|v| seen.push(v));
            seen.sort_unstable();
            assert_eq!(seen, vec![0, 63, 64, 127, 199]);
        }
        assert_eq!(s.as_sparse_ids().unwrap(), &[0, 63, 64, 127, 199]);
        assert!(s.to_dense().as_sparse_ids().is_none());
    }

    #[test]
    fn prop_roundtrip_conversions() {
        check("frontier conversions preserve membership", 25, |g| {
            let n = g.usize(1..300);
            let mut ids: Vec<u32> = (0..g.usize(0..n)).map(|_| g.u32(0..n as u32)).collect();
            ids.sort_unstable();
            ids.dedup();
            let s = VertexSubset::from_ids(n, ids.clone());
            let back = s.to_bits().to_dense().to_sparse();
            let mut bids = back.ids();
            bids.sort_unstable();
            assert_eq!(bids, ids);
        });
    }
}
