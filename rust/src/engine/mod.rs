//! Ligra-style processing engine (§4.4).
//!
//! The programming interface the paper extends: `VertexSubset` frontiers
//! with sparse/dense/bitvector representations, direction-switching
//! `EdgeMap`, `VertexMap`, and the paper's new [`segmented_edgemap`] —
//! "a new SegmentedEdgeMap operation that requires two functions: one for
//! computing partial results over a segment, and one for merging two
//! partial results".

pub mod frontier;
pub mod edgemap;
pub mod segmented_edgemap;

pub use edgemap::{edge_map, vertex_map, EdgeMapOpts};
pub use frontier::VertexSubset;
pub use segmented_edgemap::segmented_edge_map;
