//! Ligra-style processing engine (§4.4).
//!
//! The programming interface the paper extends: `VertexSubset` frontiers
//! with sparse/dense/bitvector representations, direction-switching
//! `EdgeMap`, `VertexMap`, and the paper's new [`segmented_edgemap`] —
//! "a new SegmentedEdgeMap operation that requires two functions: one for
//! computing partial results over a segment, and one for merging two
//! partial results".
//!
//! Every iterative entry point is allocation-free in the steady state:
//! `edge_map` draws all working memory from a caller-owned
//! [`EngineScratch`], and `segmented_edge_map` reuses caller-owned
//! per-segment buffers ([`crate::segment::SegmentBuffers`]) across
//! iterations.

pub mod frontier;
pub mod edgemap;
pub mod scratch;
pub mod segmented_edgemap;

pub use edgemap::{edge_map, vertex_map, EdgeMapOpts};
pub use frontier::VertexSubset;
pub use scratch::EngineScratch;
pub use segmented_edgemap::segmented_edge_map;
