//! `SegmentedEdgeMap` (§4.4): the paper's extension to the Ligra API.
//!
//! "We extended the API by adding a new SegmentedEdgeMap operation that
//! requires two functions: one for computing partial results over a
//! segment, and one for merging two partial results."
//!
//! The operation is defined for algorithms that aggregate values over the
//! neighbors of each vertex with an **associative and commutative**
//! operation. `contrib(src)` produces the per-edge partial; `merge_op`
//! folds partials (both within a segment and across segments in the
//! cache-aware merge).
//!
//! The per-segment intermediate vectors are **caller-owned**
//! ([`SegmentBuffers`], built once per prepared app) and reused across
//! iterations — CC used to re-allocate O(Σ num_dsts) of them every
//! sweep. Their contents on entry are irrelevant: the segment pass fully
//! rewrites every entry the merge reads.

use crate::graph::VertexId;
use crate::parallel::{parallel_for_cost, UnsafeSlice};
use crate::segment::{SegmentBuffers, SegmentedCsr};

/// Run a segmented aggregation over the whole graph.
///
/// For each vertex `v`: `out[v] = merge_op(init, fold of contrib(u) over
/// in-neighbors u)`. Generic in the merge operation, so `+`, `min`, `max`
/// all work. The float fast path in [`SegmentedCsr::aggregate`] is the
/// specialization used by PageRank.
///
/// `bufs` must be sized for `sg` (see [`SegmentBuffers::with_fill`]);
/// its contents on entry never influence the result.
// audit: hot-path — the generic segment-at-a-time sweep + merge; all
// working storage comes in via SegmentBuffers (hot-path-alloc lint).
pub fn segmented_edge_map<T, FC, FM>(
    sg: &SegmentedCsr,
    contrib: FC,
    merge_op: FM,
    init: T,
    bufs: &mut SegmentBuffers<T>,
    out: &mut [T],
) where
    T: Copy + Send + Sync,
    FC: Fn(VertexId) -> T + Sync,
    FM: Fn(T, T) -> T + Sync,
{
    assert_eq!(out.len(), sg.num_vertices);
    assert_eq!(
        bufs.per_segment.len(),
        sg.segments.len(),
        "SegmentBuffers built for a different partition"
    );
    for (si, (seg, buf)) in sg.segments.iter().zip(bufs.per_segment.iter_mut()).enumerate() {
        let t0 = crate::obs::recorder::timestamp();
        let nd = seg.num_dsts();
        assert_eq!(buf.len(), nd, "SegmentBuffers built for a different partition");
        let buf_slice = UnsafeSlice::new(buf);
        let total = seg.num_edges() as u64;
        let threshold = (total / (4 * crate::parallel::num_threads() as u64).max(1)).max(256);
        parallel_for_cost(
            nd,
            threshold,
            |lo, hi| seg.offsets[hi] - seg.offsets[lo],
            |lo, hi| {
                for i in lo..hi {
                    let e0 = seg.offsets[i] as usize;
                    let e1 = seg.offsets[i + 1] as usize;
                    let mut acc = init;
                    for &u in &seg.sources[e0..e1] {
                        acc = merge_op(acc, contrib(u));
                    }
                    // SAFETY: parallel_for_cost hands each dst index i to
                    // exactly one task, and i < nd == buf.len().
                    unsafe { buf_slice.write(i, acc) };
                }
            },
        );
        let buf_bytes = (nd * std::mem::size_of::<T>()) as u64;
        crate::obs::recorder::record_segment(t0, si as u64, total, buf_bytes);
    }
    // Cache-aware merge over blocks (generic variant of segment::merge).
    let t_merge = crate::obs::recorder::timestamp();
    let seg_bufs: &[Vec<T>] = &bufs.per_segment;
    let plan = &sg.merge_plan;
    out.iter_mut().for_each(|x| *x = init);
    let out_slice = UnsafeSlice::new(out);
    let nb = plan.num_blocks;
    let total: u64 = (0..nb).map(|b| plan.block_entries(b)).sum();
    let threshold = (total / (4 * crate::parallel::num_threads() as u64).max(1)).max(512);
    parallel_for_cost(
        nb,
        threshold,
        |lo, hi| (lo..hi).map(|b| plan.block_entries(b)).sum(),
        |blo, bhi| {
            for b in blo..bhi {
                for (si, (seg, vals)) in sg.segments.iter().zip(seg_bufs).enumerate() {
                    let starts = &plan.starts[si];
                    #[allow(clippy::needless_range_loop)] // parallel dst_ids/vals
                    for i in starts[b] as usize..starts[b + 1] as usize {
                        let d = seg.dst_ids[i] as usize;
                        // SAFETY: block b is handed to exactly one task,
                        // and every dst id in block b lies in that
                        // block's disjoint vertex range, so no other task
                        // can alias `out[d]`; d < out.len() by partition
                        // construction.
                        unsafe {
                            let cell = out_slice.get_mut(d);
                            *cell = merge_op(*cell, vals[i]);
                        }
                    }
                }
            }
        },
    );
    crate::obs::recorder::record_merge(t_merge);
}
// audit: hot-path-end

/// Reusable f64 entry point mirroring the Ligra-extension signature, on
/// top of the specialized float path.
pub fn segmented_edge_map_f64<FC>(
    sg: &SegmentedCsr,
    contrib: FC,
    buffers: &mut SegmentBuffers,
    init: f64,
    out: &mut [f64],
) where
    FC: Fn(VertexId) -> f64 + Sync,
{
    sg.aggregate(contrib, buffers, init, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, Csr};

    fn setup() -> (Csr, SegmentedCsr) {
        let (n, edges) = generators::rmat(9, 8, generators::RmatParams::graph500(), 14);
        let g = Csr::from_edges(n, &edges);
        let sg = SegmentedCsr::build(&g, 70);
        (g, sg)
    }

    #[test]
    fn generic_sum_matches_specialized() {
        let (g, sg) = setup();
        let n = g.num_vertices();
        let vals: Vec<f64> = (0..n).map(|i| (i % 13) as f64).collect();
        let mut generic = vec![0.0; n];
        let mut gbufs = SegmentBuffers::with_fill(&sg, 0.0);
        segmented_edge_map(&sg, |u| vals[u as usize], |a, b| a + b, 0.0, &mut gbufs, &mut generic);
        let mut bufs = SegmentBuffers::for_graph(&sg);
        let mut fast = vec![0.0; n];
        sg.aggregate(|u| vals[u as usize], &mut bufs, 0.0, &mut fast);
        assert_eq!(generic, fast);
    }

    #[test]
    fn min_aggregation() {
        let (g, sg) = setup();
        let n = g.num_vertices();
        // out[v] = min in-neighbor id (or MAX when none).
        let mut got = vec![u32::MAX; n];
        let mut bufs = SegmentBuffers::with_fill(&sg, 0u32);
        segmented_edge_map(&sg, |u| u, |a, b| a.min(b), u32::MAX, &mut bufs, &mut got);
        let t = g.transpose();
        for v in 0..n {
            let expect = t.neighbors(v as u32).iter().copied().min().unwrap_or(u32::MAX);
            assert_eq!(got[v], expect, "v={v}");
        }
    }

    #[test]
    fn count_aggregation_u64() {
        let (g, sg) = setup();
        let n = g.num_vertices();
        let mut got = vec![0u64; n];
        let mut bufs = SegmentBuffers::with_fill(&sg, 0u64);
        segmented_edge_map(&sg, |_| 1u64, |a, b| a + b, 0, &mut bufs, &mut got);
        let indeg = g.in_degrees();
        for v in 0..n {
            assert_eq!(got[v], indeg[v] as u64);
        }
    }

    /// Buffer reuse across calls — including buffers pre-filled with
    /// garbage — never leaks stale state into the result.
    #[test]
    fn reused_buffers_match_fresh_even_when_poisoned() {
        let (g, sg) = setup();
        let n = g.num_vertices();
        let vals: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2654435761) % 97).collect();
        let mut want = vec![u32::MAX; n];
        let mut fresh = SegmentBuffers::with_fill(&sg, 0u32);
        let min = |a: u32, b: u32| a.min(b);
        segmented_edge_map(&sg, |u| vals[u as usize], min, u32::MAX, &mut fresh, &mut want);
        let mut reused = SegmentBuffers::with_fill(&sg, 0u32);
        let mut got = vec![0u32; n];
        for round in 0..3u32 {
            // Poison: garbage everywhere the previous call wrote.
            for buf in &mut reused.per_segment {
                for (i, x) in buf.iter_mut().enumerate() {
                    *x = (i as u32).wrapping_mul(round.wrapping_add(0x9E37));
                }
            }
            got.fill(round);
            segmented_edge_map(&sg, |u| vals[u as usize], min, u32::MAX, &mut reused, &mut got);
            assert_eq!(got, want, "round {round}");
        }
    }
}
