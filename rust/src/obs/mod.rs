//! Structured observability: engine trace spans, versioned run reports,
//! and hardware PMU counters.
//!
//! Three pieces, one goal — make what a run learns about itself
//! machine-readable instead of discarded or flattened into a log line:
//!
//! - [`recorder`]: per-thread, lock-free ring-buffer span recorder.
//!   Instrumentation points live in the `edge_map` direction switch, the
//!   segmented aggregation loop, the job pipeline, and the artifact
//!   store; all compile down to one relaxed atomic load when recording
//!   is off, preserving the zero-allocation steady state.
//! - [`report`]: the `cagra-run` v1 JSON schema — phase timings,
//!   per-iteration engine counters, store activity, and the
//!   memory-system evidence with its provenance (`stall_source`).
//!   [`chrome`] exports the same timeline as Chrome `trace_event` JSON
//!   for flamegraph-style inspection.
//! - [`pmu`]: real cycles / instructions / LLC counters via a
//!   dependency-free `perf_event_open` reader, so the simulated stall
//!   model can be validated against hardware (DESIGN.md §3).

pub mod chrome;
pub mod pmu;
pub mod recorder;
pub mod report;

pub use pmu::{PmuCounters, PmuGroup, PmuMetrics};
pub use report::RunReport;
