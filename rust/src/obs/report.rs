//! Versioned machine-readable run reports (`cagra-run` v1).
//!
//! Where `bench/report.rs` records *how fast* a suite ran, this format
//! records *what one job did*: phase timings, the per-iteration engine
//! counter timeline from [`crate::obs::recorder`], per-artifact store
//! activity, and the memory-system evidence — simulated
//! [`StallEstimate`] and/or hardware [`PmuMetrics`] — with a
//! `stall_source` tag saying which one backs the numbers.
//!
//! Same contract as the bench format: hand-rolled over
//! [`crate::util::json`] (no serde), versioned so a newer writer can
//! never be silently misread, strict on parse, and byte-stable across
//! encode→parse→encode.
//!
//! File layout (`FORMAT_NAME` / `FORMAT_VERSION`):
//!
//! ```json
//! {
//!   "format": "cagra-run",
//!   "version": 1,
//!   "git_sha": "f41d867…",
//!   "app": "bfs/reordering+bitvector",
//!   "dataset": "twitter-sim",
//!   "scale": 0.25,
//!   "threads": 4,
//!   "edges": 47283456,
//!   "summary": 12.0,
//!   "stall_source": "simulated",
//!   "iter_seconds": [0.014, 0.009],
//!   "phases": [{"name": "load", "seconds": 0.21, "count": 1}],
//!   "scratch_bytes": 1048576,
//!   "simulated": {"accesses": 1000, "stall_cycles": 52000.0,
//!                 "llc_misses": 210, "llc_miss_rate": 0.21},
//!   "pmu": {"phases": [...], "iters": [...]},
//!   "store": {"hits": 2, "misses": 1, ...},
//!   "faults": [{"site": "store.write", "fires": 3}],
//!   "events": [{"kind": "edge_map", "name": "edge_map", "t_us": 1200,
//!               "dur_us": 340, "a": 10, "b": 80, "c": 7, "d": 1}],
//!   "events_dropped": 0
//! }
//! ```
//!
//! Optional sections (`scratch_bytes`, `simulated`, `pmu`, `store`,
//! `faults`) are omitted entirely when absent, never encoded as `null`.

use crate::cache::StallEstimate;
use crate::coordinator::{JobResult, JobSpec};
use crate::obs::pmu::{PmuCounters, PmuMetrics};
use crate::obs::recorder;
use crate::store::StoreStats;
use crate::util::json::{self, Value};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Format discriminator in every run report.
pub const FORMAT_NAME: &str = "cagra-run";
/// Schema version this build writes and the newest it can read.
pub const FORMAT_VERSION: u64 = 1;

/// `kind` tags a report may carry (the recorder's event kinds).
pub const EVENT_KINDS: [&str; 6] = ["phase", "edge_map", "segment", "merge", "artifact", "iter"];

/// One pipeline phase: accumulated seconds and invocation count.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseEntry {
    pub name: String,
    pub seconds: f64,
    pub count: u64,
}

/// One recorder span, schema-side: `kind` is one of [`EVENT_KINDS`] and
/// `a..d` are the kind-specific counters documented on
/// [`recorder::EventKind`].
#[derive(Debug, Clone, PartialEq)]
pub struct TimelineEvent {
    pub kind: String,
    pub name: String,
    pub t_us: u64,
    pub dur_us: u64,
    pub a: u64,
    pub b: u64,
    pub c: u64,
    pub d: u64,
}

impl TimelineEvent {
    /// Convert a recorder event; artifact events take their file name as
    /// the span name.
    pub fn from_recorded(ev: recorder::Event) -> TimelineEvent {
        let name = if ev.detail.is_empty() {
            ev.name.to_string()
        } else {
            ev.detail
        };
        TimelineEvent {
            kind: ev.kind.as_str().to_string(),
            name,
            t_us: ev.start_us,
            dur_us: ev.dur_us,
            a: ev.a,
            b: ev.b,
            c: ev.c,
            d: ev.d,
        }
    }

    fn to_value(&self) -> Value {
        Value::Obj(vec![
            ("kind".to_string(), Value::Str(self.kind.clone())),
            ("name".to_string(), Value::Str(self.name.clone())),
            ("t_us".to_string(), Value::Num(self.t_us as f64)),
            ("dur_us".to_string(), Value::Num(self.dur_us as f64)),
            ("a".to_string(), Value::Num(self.a as f64)),
            ("b".to_string(), Value::Num(self.b as f64)),
            ("c".to_string(), Value::Num(self.c as f64)),
            ("d".to_string(), Value::Num(self.d as f64)),
        ])
    }

    fn from_value(v: &Value) -> Result<TimelineEvent> {
        let kind = require_str(v, "kind")?;
        if !EVENT_KINDS.contains(&kind.as_str()) {
            bail!("unknown event kind {kind:?}");
        }
        Ok(TimelineEvent {
            name: require_str(v, "name")?,
            t_us: require_u64(v, &kind, "t_us")?,
            dur_us: require_u64(v, &kind, "dur_us")?,
            a: require_u64(v, &kind, "a")?,
            b: require_u64(v, &kind, "b")?,
            c: require_u64(v, &kind, "c")?,
            d: require_u64(v, &kind, "d")?,
            kind,
        })
    }
}

/// Everything one `run_job` learned about itself, in the order the
/// schema encodes it.
#[derive(Debug, Clone, PartialEq)]
pub struct RunReport {
    pub git_sha: String,
    /// `app/variant` as reported by `Metrics`.
    pub app: String,
    pub dataset: String,
    pub scale: f64,
    pub threads: usize,
    pub edges: u64,
    /// The job's app-defined summary value (ranks sum, reached count, …).
    pub summary: f64,
    pub iter_seconds: Vec<f64>,
    pub phases: Vec<PhaseEntry>,
    pub scratch_bytes: Option<u64>,
    /// Cache-simulator stall estimate (when the job ran `--analyze`).
    pub simulated: Option<StallEstimate>,
    /// Hardware counters (when `--pmu` was requested and available).
    pub pmu: Option<PmuMetrics>,
    pub store: Option<StoreStats>,
    /// Failpoint trigger counts (site, fires) when the job ran under
    /// injected faults ([`crate::fault`]). Empty — and omitted from the
    /// encoding — in normal operation.
    pub faults: Vec<(String, u64)>,
    pub events: Vec<TimelineEvent>,
    /// Events the recorder ring overwrote (0 = complete timeline).
    pub events_dropped: u64,
}

impl RunReport {
    /// Build a report for a finished job, draining the recorder's ring
    /// on the calling thread (which must be the thread that ran the job).
    pub fn from_job(spec: &JobSpec, result: &JobResult) -> RunReport {
        let (events, dropped) = recorder::drain();
        let m = &result.metrics;
        RunReport {
            git_sha: crate::bench::report::git_sha(),
            app: m.app.clone().unwrap_or_else(|| "unknown".to_string()),
            dataset: spec.dataset.clone(),
            scale: spec.scale,
            threads: crate::parallel::num_threads(),
            edges: m.edges,
            summary: result.summary,
            iter_seconds: m.iter_seconds.clone(),
            phases: m
                .phases
                .report()
                .into_iter()
                .map(|(name, seconds, _)| {
                    let count = m.phases.count(&name);
                    PhaseEntry { name, seconds, count }
                })
                .collect(),
            scratch_bytes: m.scratch_bytes,
            simulated: m.stalls,
            pmu: m.pmu.clone(),
            store: m.store,
            faults: m.faults.clone(),
            events: events.into_iter().map(TimelineEvent::from_recorded).collect(),
            events_dropped: dropped,
        }
    }

    /// Which measurement backs the memory-system numbers: `"pmu"`
    /// (hardware beats simulation when both are present), `"simulated"`,
    /// or `"none"`.
    pub fn stall_source(&self) -> &'static str {
        if self.pmu.is_some() {
            "pmu"
        } else if self.simulated.is_some() {
            "simulated"
        } else {
            "none"
        }
    }

    /// Encode to the versioned JSON format. Errors on non-finite floats
    /// (which would otherwise lossily encode as `null`).
    pub fn to_json(&self) -> Result<String> {
        for (field, v) in [("scale", self.scale), ("summary", self.summary)] {
            if !v.is_finite() {
                bail!("run report: non-finite {field}");
            }
        }
        if self.iter_seconds.iter().any(|s| !s.is_finite()) {
            bail!("run report: non-finite iteration time");
        }
        for p in &self.phases {
            if !p.seconds.is_finite() {
                bail!("run report: non-finite seconds for phase {:?}", p.name);
            }
        }
        if let Some(s) = &self.simulated {
            if !s.stall_cycles.is_finite() || !s.llc_miss_rate.is_finite() {
                bail!("run report: non-finite simulated stall estimate");
            }
        }
        let mut fields = vec![
            ("format".to_string(), Value::Str(FORMAT_NAME.to_string())),
            ("version".to_string(), Value::Num(FORMAT_VERSION as f64)),
            ("git_sha".to_string(), Value::Str(self.git_sha.clone())),
            ("app".to_string(), Value::Str(self.app.clone())),
            ("dataset".to_string(), Value::Str(self.dataset.clone())),
            ("scale".to_string(), Value::Num(self.scale)),
            ("threads".to_string(), Value::Num(self.threads as f64)),
            ("edges".to_string(), Value::Num(self.edges as f64)),
            ("summary".to_string(), Value::Num(self.summary)),
            (
                "stall_source".to_string(),
                Value::Str(self.stall_source().to_string()),
            ),
            (
                "iter_seconds".to_string(),
                Value::Arr(self.iter_seconds.iter().map(|s| Value::Num(*s)).collect()),
            ),
            (
                "phases".to_string(),
                Value::Arr(
                    self.phases
                        .iter()
                        .map(|p| {
                            Value::Obj(vec![
                                ("name".to_string(), Value::Str(p.name.clone())),
                                ("seconds".to_string(), Value::Num(p.seconds)),
                                ("count".to_string(), Value::Num(p.count as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if let Some(b) = self.scratch_bytes {
            fields.push(("scratch_bytes".to_string(), Value::Num(b as f64)));
        }
        if let Some(s) = &self.simulated {
            fields.push(("simulated".to_string(), stall_to_value(s)));
        }
        if let Some(p) = &self.pmu {
            fields.push(("pmu".to_string(), pmu_to_value(p)));
        }
        if let Some(s) = &self.store {
            fields.push(("store".to_string(), store_to_value(s)));
        }
        if !self.faults.is_empty() {
            fields.push((
                "faults".to_string(),
                Value::Arr(
                    self.faults
                        .iter()
                        .map(|(site, n)| {
                            Value::Obj(vec![
                                ("site".to_string(), Value::Str(site.clone())),
                                ("fires".to_string(), Value::Num(*n as f64)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        fields.push((
            "events".to_string(),
            Value::Arr(self.events.iter().map(TimelineEvent::to_value).collect()),
        ));
        fields.push((
            "events_dropped".to_string(),
            Value::Num(self.events_dropped as f64),
        ));
        let mut out = Value::Obj(fields).render();
        out.push('\n');
        Ok(out)
    }

    /// Strict parse: wrong format tag, unsupported version, missing
    /// fields, unknown event kinds, or an inconsistent `stall_source`
    /// all error.
    pub fn parse(input: &str) -> Result<RunReport> {
        let v = json::parse(input).context("run report is not valid JSON")?;
        let format = v
            .get("format")
            .and_then(Value::as_str)
            .context("missing format tag")?;
        if format != FORMAT_NAME {
            bail!("not a run report (format {format:?}, expected {FORMAT_NAME:?})");
        }
        let version = v
            .get("version")
            .and_then(Value::as_u64)
            .context("missing format version")?;
        if version > FORMAT_VERSION {
            bail!("run report version {version} is newer than this build (max {FORMAT_VERSION})");
        }
        let app = require_str(&v, "app")?;
        let phases = v
            .get("phases")
            .and_then(Value::as_arr)
            .context("missing phases array")?
            .iter()
            .map(|p| {
                Ok(PhaseEntry {
                    name: require_str(p, "name")?,
                    seconds: require_num(p, &app, "seconds")?,
                    count: require_u64(p, &app, "count")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let iter_seconds = v
            .get("iter_seconds")
            .and_then(Value::as_arr)
            .context("missing iter_seconds array")?
            .iter()
            .map(|s| s.as_f64().context("iter_seconds entries must be numbers"))
            .collect::<Result<Vec<_>>>()?;
        let events = v
            .get("events")
            .and_then(Value::as_arr)
            .context("missing events array")?
            .iter()
            .map(TimelineEvent::from_value)
            .collect::<Result<Vec<_>>>()?;
        let report = RunReport {
            git_sha: require_str(&v, "git_sha")?,
            dataset: require_str(&v, "dataset")?,
            scale: require_num(&v, &app, "scale")?,
            threads: require_u64(&v, &app, "threads")? as usize,
            edges: require_u64(&v, &app, "edges")?,
            summary: require_num(&v, &app, "summary")?,
            iter_seconds,
            phases,
            scratch_bytes: match v.get("scratch_bytes") {
                None => None,
                Some(b) => Some(b.as_u64().context("scratch_bytes must be a u64")?),
            },
            simulated: match v.get("simulated") {
                None => None,
                Some(s) => Some(stall_from_value(s)?),
            },
            pmu: match v.get("pmu") {
                None => None,
                Some(p) => Some(pmu_from_value(p)?),
            },
            store: match v.get("store") {
                None => None,
                Some(s) => Some(store_from_value(s)?),
            },
            // Absent unless the run injected faults (and from reports
            // written before failpoints existed): default to empty.
            faults: match v.get("faults").and_then(Value::as_arr) {
                None => Vec::new(),
                Some(arr) => arr
                    .iter()
                    .map(|f| {
                        Ok((
                            require_str(f, "site")?,
                            require_u64(f, "faults", "fires")?,
                        ))
                    })
                    .collect::<Result<Vec<_>>>()?,
            },
            events,
            events_dropped: require_u64(&v, &app, "events_dropped")?,
            app,
        };
        let declared = require_str(&v, "stall_source")?;
        if declared != report.stall_source() {
            bail!(
                "stall_source {declared:?} inconsistent with report contents (expected {:?})",
                report.stall_source()
            );
        }
        Ok(report)
    }

    /// Load and parse one report file.
    pub fn load(path: &Path) -> Result<RunReport> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    /// Encode and write to `path`.
    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        std::fs::write(path, self.to_json()?)
            .with_context(|| format!("writing {}", path.display()))
    }
}

fn stall_to_value(s: &StallEstimate) -> Value {
    Value::Obj(vec![
        ("accesses".to_string(), Value::Num(s.accesses as f64)),
        ("stall_cycles".to_string(), Value::Num(s.stall_cycles)),
        ("llc_misses".to_string(), Value::Num(s.llc_misses as f64)),
        ("llc_miss_rate".to_string(), Value::Num(s.llc_miss_rate)),
    ])
}

fn stall_from_value(v: &Value) -> Result<StallEstimate> {
    Ok(StallEstimate {
        accesses: require_u64(v, "simulated", "accesses")?,
        stall_cycles: require_num(v, "simulated", "stall_cycles")?,
        llc_misses: require_u64(v, "simulated", "llc_misses")?,
        llc_miss_rate: require_num(v, "simulated", "llc_miss_rate")?,
    })
}

fn counters_to_value(c: &PmuCounters) -> Vec<(String, Value)> {
    vec![
        ("cycles".to_string(), Value::Num(c.cycles as f64)),
        ("instructions".to_string(), Value::Num(c.instructions as f64)),
        (
            "cache_references".to_string(),
            Value::Num(c.cache_references as f64),
        ),
        ("cache_misses".to_string(), Value::Num(c.cache_misses as f64)),
    ]
}

fn counters_from_value(v: &Value, ctx: &str) -> Result<PmuCounters> {
    Ok(PmuCounters {
        cycles: require_u64(v, ctx, "cycles")?,
        instructions: require_u64(v, ctx, "instructions")?,
        cache_references: require_u64(v, ctx, "cache_references")?,
        cache_misses: require_u64(v, ctx, "cache_misses")?,
    })
}

fn pmu_to_value(p: &PmuMetrics) -> Value {
    Value::Obj(vec![
        (
            "phases".to_string(),
            Value::Arr(
                p.phases
                    .iter()
                    .map(|(name, c)| {
                        let mut fields = vec![("name".to_string(), Value::Str(name.clone()))];
                        fields.extend(counters_to_value(c));
                        Value::Obj(fields)
                    })
                    .collect(),
            ),
        ),
        (
            "iters".to_string(),
            Value::Arr(
                p.iters
                    .iter()
                    .map(|c| Value::Obj(counters_to_value(c)))
                    .collect(),
            ),
        ),
    ])
}

fn pmu_from_value(v: &Value) -> Result<PmuMetrics> {
    let phases = v
        .get("phases")
        .and_then(Value::as_arr)
        .context("pmu: missing phases array")?
        .iter()
        .map(|p| {
            let name = require_str(p, "name")?;
            let c = counters_from_value(p, &name)?;
            Ok((name, c))
        })
        .collect::<Result<Vec<_>>>()?;
    let iters = v
        .get("iters")
        .and_then(Value::as_arr)
        .context("pmu: missing iters array")?
        .iter()
        .map(|c| counters_from_value(c, "pmu iter"))
        .collect::<Result<Vec<_>>>()?;
    Ok(PmuMetrics { phases, iters })
}

fn store_to_value(s: &StoreStats) -> Value {
    Value::Obj(vec![
        ("hits".to_string(), Value::Num(s.hits as f64)),
        ("misses".to_string(), Value::Num(s.misses as f64)),
        ("evictions".to_string(), Value::Num(s.evictions as f64)),
        ("bytes_read".to_string(), Value::Num(s.bytes_read as f64)),
        ("bytes_mapped".to_string(), Value::Num(s.bytes_mapped as f64)),
        ("bytes_written".to_string(), Value::Num(s.bytes_written as f64)),
        ("entries".to_string(), Value::Num(s.entries as f64)),
        (
            "resident_bytes".to_string(),
            Value::Num(s.resident_bytes as f64),
        ),
        ("cap_bytes".to_string(), Value::Num(s.cap_bytes as f64)),
        ("quarantined".to_string(), Value::Num(s.quarantined as f64)),
        ("rebuilds".to_string(), Value::Num(s.rebuilds as f64)),
    ])
}

fn store_from_value(v: &Value) -> Result<StoreStats> {
    Ok(StoreStats {
        hits: require_u64(v, "store", "hits")?,
        misses: require_u64(v, "store", "misses")?,
        evictions: require_u64(v, "store", "evictions")?,
        bytes_read: require_u64(v, "store", "bytes_read")?,
        // Absent from reports written before the zero-copy store: default,
        // don't reject, so archived runs stay loadable.
        bytes_mapped: v.get("bytes_mapped").and_then(Value::as_u64).unwrap_or(0),
        bytes_written: require_u64(v, "store", "bytes_written")?,
        entries: require_u64(v, "store", "entries")?,
        resident_bytes: require_u64(v, "store", "resident_bytes")?,
        cap_bytes: require_u64(v, "store", "cap_bytes")?,
        // Absent from reports written before store self-healing: default,
        // don't reject, so archived runs stay loadable.
        quarantined: v.get("quarantined").and_then(Value::as_u64).unwrap_or(0),
        rebuilds: v.get("rebuilds").and_then(Value::as_u64).unwrap_or(0),
    })
}

fn require_str(v: &Value, key: &str) -> Result<String> {
    v.get(key)
        .and_then(Value::as_str)
        .map(str::to_string)
        .with_context(|| format!("missing string field {key:?}"))
}

fn require_num(v: &Value, ctx: &str, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(Value::as_f64)
        .with_context(|| format!("{ctx}: missing numeric field {key:?}"))
}

fn require_u64(v: &Value, ctx: &str, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(Value::as_u64)
        .with_context(|| format!("{ctx}: missing integer field {key:?}"))
}

#[cfg(test)]
pub(crate) fn sample_report() -> RunReport {
    RunReport {
        git_sha: "deadbeef".into(),
        app: "bfs/reordering+bitvector".into(),
        dataset: "twitter-sim".into(),
        scale: 0.25,
        threads: 4,
        edges: 47_283_456,
        summary: 1024.0,
        iter_seconds: vec![0.014, 0.009],
        phases: vec![
            PhaseEntry {
                name: "load".into(),
                seconds: 0.21,
                count: 1,
            },
            PhaseEntry {
                name: "preprocess".into(),
                seconds: 0.02,
                count: 1,
            },
        ],
        scratch_bytes: Some(1 << 20),
        simulated: Some(StallEstimate {
            accesses: 1000,
            stall_cycles: 52_000.0,
            llc_misses: 210,
            llc_miss_rate: 0.21,
        }),
        pmu: Some(PmuMetrics {
            phases: vec![(
                "load".into(),
                PmuCounters {
                    cycles: 1_000_000,
                    instructions: 2_000_000,
                    cache_references: 5_000,
                    cache_misses: 800,
                },
            )],
            iters: vec![PmuCounters {
                cycles: 400_000,
                instructions: 900_000,
                cache_references: 2_200,
                cache_misses: 300,
            }],
        }),
        store: Some(StoreStats {
            hits: 2,
            misses: 1,
            evictions: 0,
            bytes_read: 4096,
            bytes_mapped: 8192,
            bytes_written: 2048,
            entries: 3,
            resident_bytes: 6144,
            cap_bytes: 1 << 30,
            quarantined: 1,
            rebuilds: 1,
        }),
        faults: vec![("store.write".into(), 3)],
        events: vec![
            TimelineEvent {
                kind: "phase".into(),
                name: "load".into(),
                t_us: 0,
                dur_us: 210_000,
                a: 0,
                b: 0,
                c: 0,
                d: 0,
            },
            TimelineEvent {
                kind: "edge_map".into(),
                name: "edge_map".into(),
                t_us: 230_000,
                dur_us: 340,
                a: 10,
                b: 80,
                c: 7,
                d: 1,
            },
            TimelineEvent {
                kind: "artifact".into(),
                name: "degree-perm.v1.art".into(),
                t_us: 231_000,
                dur_us: 1_500,
                a: 1,
                b: 0,
                c: 0,
                d: 0,
            },
        ],
        events_dropped: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_parse_encode_is_byte_stable() {
        let r = sample_report();
        let once = r.to_json().unwrap();
        let back = RunReport::parse(&once).unwrap();
        assert_eq!(back, r);
        assert_eq!(back.to_json().unwrap(), once);
    }

    #[test]
    fn stall_source_tracks_contents() {
        let mut r = sample_report();
        assert_eq!(r.stall_source(), "pmu");
        r.pmu = None;
        assert_eq!(r.stall_source(), "simulated");
        r.simulated = None;
        assert_eq!(r.stall_source(), "none");
        // And each variant still round-trips byte-stably.
        let once = r.to_json().unwrap();
        assert_eq!(RunReport::parse(&once).unwrap().to_json().unwrap(), once);
    }

    #[test]
    fn version_and_format_are_enforced() {
        let good = sample_report().to_json().unwrap();
        let newer = good.replace("\"version\": 1", "\"version\": 99");
        assert!(RunReport::parse(&newer).is_err(), "future version accepted");
        let alien = good.replace("cagra-run", "other-tool");
        assert!(RunReport::parse(&alien).is_err(), "foreign format accepted");
    }

    #[test]
    fn inconsistent_stall_source_is_rejected() {
        let mut r = sample_report();
        r.pmu = None;
        r.simulated = None;
        let lying = r
            .to_json()
            .unwrap()
            .replace("\"stall_source\": \"none\"", "\"stall_source\": \"pmu\"");
        assert!(RunReport::parse(&lying).is_err(), "accepted a stall_source lie");
    }

    #[test]
    fn unknown_event_kind_is_rejected() {
        let bad = sample_report()
            .to_json()
            .unwrap()
            .replace("\"kind\": \"edge_map\"", "\"kind\": \"mystery\"");
        assert!(RunReport::parse(&bad).is_err(), "accepted unknown event kind");
    }

    #[test]
    fn non_finite_floats_refuse_to_encode() {
        let mut r = sample_report();
        r.iter_seconds[0] = f64::NAN;
        assert!(r.to_json().is_err());
        let mut r = sample_report();
        r.simulated = Some(StallEstimate {
            accesses: 1,
            stall_cycles: f64::INFINITY,
            llc_misses: 1,
            llc_miss_rate: 0.5,
        });
        assert!(r.to_json().is_err());
    }
}
