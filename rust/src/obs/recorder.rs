//! Per-thread trace-span recorder: the engine's counters with timestamps.
//!
//! Instrumentation points (the `edge_map` direction switch, the segment
//! loop and cache-aware merge, the job pipeline's phases, the artifact
//! store) call the typed `record_*` helpers below. When recording is
//! **disabled** (the default) every helper early-returns after one relaxed
//! atomic load — no clock read, no thread-local access, no allocation —
//! so the zero-allocation steady state proven by `tests/zero_alloc.rs`
//! holds with the instrumentation compiled in.
//!
//! When **enabled**, events land in a per-thread ring buffer (no locks,
//! no cross-thread traffic): all current instrumentation points execute
//! on the job's driver thread (the engine parallelizes *inside* an
//! `edge_map` level or a segment pass, never across them), so draining
//! from that same thread observes the complete, ordered timeline. The
//! ring holds [`RING_CAPACITY`] events; past that the oldest events are
//! overwritten and counted as dropped — a bounded-memory guarantee, not a
//! silent truncation ([`drain`] reports the count).

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Events retained per thread before the ring starts overwriting.
pub const RING_CAPACITY: usize = 1 << 16;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Process-wide clock origin: all timestamps are µs since the first call.
fn origin() -> Instant {
    static ORIGIN: OnceLock<Instant> = OnceLock::new();
    *ORIGIN.get_or_init(Instant::now)
}

/// Microseconds since the recorder's clock origin.
pub fn now_us() -> u64 {
    origin().elapsed().as_micros() as u64
}

/// Is recording on? One relaxed load — this is the entire disabled-path
/// cost of every instrumentation point.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn recording on (pins the clock origin first, so no event can carry
/// a timestamp from before enablement).
pub fn enable() {
    origin();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn recording off. Rings keep their contents until drained.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Span-start timestamp: the current µs clock when enabled, 0 when
/// disabled (the matching `record_*` call will early-return anyway).
#[inline]
pub fn timestamp() -> u64 {
    if enabled() {
        now_us()
    } else {
        0
    }
}

/// What an [`Event`] describes. The string forms are the `kind` tags in
/// the `cagra-run` report schema.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A job-pipeline phase (load / fingerprint / preprocess / simulate).
    Phase,
    /// One `edge_map` level: a = input frontier size, b = out-work
    /// estimate (frontier out-degree sum), c = output frontier size
    /// (== the push-mode atomic-cursor occupancy), d = 1 if the switch
    /// chose dense/pull.
    EdgeMapLevel,
    /// One segment pass: a = segment index, b = edges processed,
    /// c = intermediate-buffer bytes.
    Segment,
    /// The cache-aware merge after the segment passes.
    Merge,
    /// One artifact-store lookup: a = 1 on hit, 0 on build; duration is
    /// read time (hit) or build+write time (miss).
    Artifact,
    /// One execution unit (iteration or source traversal): a = index,
    /// b = source vertex for per-source apps.
    Iter,
}

impl EventKind {
    pub fn as_str(self) -> &'static str {
        match self {
            EventKind::Phase => "phase",
            EventKind::EdgeMapLevel => "edge_map",
            EventKind::Segment => "segment",
            EventKind::Merge => "merge",
            EventKind::Artifact => "artifact",
            EventKind::Iter => "iter",
        }
    }
}

/// One recorded span. `a..d` are kind-specific counters (see
/// [`EventKind`]); `detail` is empty except for artifact events (the
/// artifact file name).
#[derive(Debug, Clone)]
pub struct Event {
    pub kind: EventKind,
    pub name: &'static str,
    pub detail: String,
    pub start_us: u64,
    pub dur_us: u64,
    pub a: u64,
    pub b: u64,
    pub c: u64,
    pub d: u64,
}

struct Ring {
    buf: Vec<Event>,
    /// Oldest slot once the ring is full (next overwrite target).
    head: usize,
    dropped: u64,
}

thread_local! {
    static RING: RefCell<Ring> = const {
        RefCell::new(Ring { buf: Vec::new(), head: 0, dropped: 0 })
    };
}

fn push(ev: Event) {
    RING.with(|r| {
        let mut r = r.borrow_mut();
        if r.buf.len() < RING_CAPACITY {
            r.buf.push(ev);
        } else {
            let head = r.head;
            r.buf[head] = ev;
            r.head = (head + 1) % RING_CAPACITY;
            r.dropped += 1;
        }
    });
}

/// Take this thread's events (chronological) and the count of events the
/// ring overwrote. Resets the ring.
pub fn drain() -> (Vec<Event>, u64) {
    RING.with(|r| {
        let mut r = r.borrow_mut();
        let head = r.head;
        let dropped = r.dropped;
        let mut out = std::mem::take(&mut r.buf);
        // With wrap-around, buf[head..] holds the oldest events.
        out.rotate_left(head);
        r.head = 0;
        r.dropped = 0;
        (out, dropped)
    })
}

fn record(kind: EventKind, name: &'static str, detail: String, start_us: u64, counters: [u64; 4]) {
    let dur_us = now_us().saturating_sub(start_us);
    let [a, b, c, d] = counters;
    push(Event {
        kind,
        name,
        detail,
        start_us,
        dur_us,
        a,
        b,
        c,
        d,
    });
}

/// A job-pipeline phase span.
#[inline]
pub fn record_phase(name: &'static str, start_us: u64) {
    if !enabled() {
        return;
    }
    record(EventKind::Phase, name, String::new(), start_us, [0; 4]);
}

/// One `edge_map` level (see [`EventKind::EdgeMapLevel`] for the fields).
#[inline]
pub fn record_edge_map_level(
    start_us: u64,
    frontier: u64,
    out_work: u64,
    next_frontier: u64,
    dense: bool,
) {
    if !enabled() {
        return;
    }
    record(
        EventKind::EdgeMapLevel,
        "edge_map",
        String::new(),
        start_us,
        [frontier, out_work, next_frontier, dense as u64],
    );
}

/// One segment pass of a segmented aggregation.
#[inline]
pub fn record_segment(start_us: u64, index: u64, edges: u64, buffer_bytes: u64) {
    if !enabled() {
        return;
    }
    record(
        EventKind::Segment,
        "segment",
        String::new(),
        start_us,
        [index, edges, buffer_bytes, 0],
    );
}

/// The cache-aware merge following the segment passes.
#[inline]
pub fn record_merge(start_us: u64) {
    if !enabled() {
        return;
    }
    record(EventKind::Merge, "merge", String::new(), start_us, [0; 4]);
}

/// One execution unit (iteration / source traversal).
#[inline]
pub fn record_iter(start_us: u64, index: u64, aux: u64) {
    if !enabled() {
        return;
    }
    record(EventKind::Iter, "iter", String::new(), start_us, [index, aux, 0, 0]);
}

/// One artifact-store lookup (hit or build); `path`'s file name becomes
/// the event detail.
#[inline]
pub fn record_artifact(start_us: u64, path: &std::path::Path, hit: bool) {
    if !enabled() {
        return;
    }
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_default();
    record(EventKind::Artifact, "artifact", name, start_us, [hit as u64, 0, 0, 0]);
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: these tests only ever *enable* the global flag (rings are
    // per-thread, so concurrently-enabled lib tests cannot interfere);
    // the disabled ⇒ strictly-no-op property is asserted where it can be
    // raced by nothing: the single-test `tests/zero_alloc.rs` binary.

    #[test]
    fn records_and_drains_in_order() {
        enable();
        drain(); // isolate from any earlier recording on this thread
        let t0 = timestamp();
        record_phase("load", t0);
        let t1 = timestamp();
        record_edge_map_level(t1, 10, 80, 7, true);
        record_artifact(t1, std::path::Path::new("/store/abc.v1.art"), true);
        let (events, dropped) = drain();
        assert_eq!(dropped, 0);
        assert_eq!(events.len(), 3);
        assert_eq!(events[0].kind, EventKind::Phase);
        assert_eq!(events[0].name, "load");
        assert_eq!(events[1].kind, EventKind::EdgeMapLevel);
        assert_eq!((events[1].a, events[1].b, events[1].c, events[1].d), (10, 80, 7, 1));
        assert_eq!(events[2].detail, "abc.v1.art");
        assert_eq!(events[2].a, 1);
        assert!(events[0].start_us <= events[1].start_us);
        // Drained: the ring is empty again.
        assert!(drain().0.is_empty());
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        enable();
        drain();
        let extra = 5u64;
        for i in 0..(RING_CAPACITY as u64 + extra) {
            record_iter(now_us(), i, 0);
        }
        let (events, dropped) = drain();
        assert_eq!(events.len(), RING_CAPACITY);
        assert_eq!(dropped, extra);
        // Oldest `extra` events were overwritten; order is preserved.
        assert_eq!(events[0].a, extra);
        assert_eq!(events.last().unwrap().a, RING_CAPACITY as u64 + extra - 1);
    }
}
