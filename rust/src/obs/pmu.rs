//! Hardware PMU counters via a dependency-free `perf_event_open` reader.
//!
//! The stall model in `cache/` simulates what the paper *measured* with
//! `perf`; this module closes the loop by reading the real counters —
//! cycles, instructions, LLC references and misses — so the analytical
//! model can be validated against hardware instead of against itself
//! (DESIGN.md §3).
//!
//! No `perf_event` crate, no libc crate: the syscall and the ioctls are
//! declared directly against the C runtime the binary already links.
//! The whole path is feature-gated (`pmu`, on by default) and runtime
//! probed: in containers and CI runners where `perf_event_open` is
//! blocked (seccomp, `perf_event_paranoid`), [`PmuGroup::open`] returns
//! `None` and callers fall back to the simulated estimate.
//!
//! Each counter gets its own fd (no perf group read): on VMs it is
//! common for cycles to be available while cache events are not, and
//! independent fds let the available subset degrade gracefully —
//! unavailable counters simply read 0.

/// One sample of the hardware counters. Counters whose event could not
/// be opened (or read) report 0.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PmuCounters {
    pub cycles: u64,
    pub instructions: u64,
    pub cache_references: u64,
    pub cache_misses: u64,
}

impl PmuCounters {
    pub fn add(&mut self, other: PmuCounters) {
        self.cycles += other.cycles;
        self.instructions += other.instructions;
        self.cache_references += other.cache_references;
        self.cache_misses += other.cache_misses;
    }

    /// LLC miss rate over this sample, if references were counted.
    pub fn llc_miss_rate(&self) -> Option<f64> {
        if self.cache_references == 0 {
            None
        } else {
            Some(self.cache_misses as f64 / self.cache_references as f64)
        }
    }
}

/// Per-phase and per-execution-unit hardware counters for one job.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PmuMetrics {
    /// Named pipeline phases (load, preprocess, ...).
    pub phases: Vec<(String, PmuCounters)>,
    /// One sample per iteration / source traversal, in execution order.
    pub iters: Vec<PmuCounters>,
}

impl PmuMetrics {
    /// Sum over all phases and execution units.
    pub fn total(&self) -> PmuCounters {
        let mut t = PmuCounters::default();
        for (_, c) in &self.phases {
            t.add(*c);
        }
        for c in &self.iters {
            t.add(*c);
        }
        t
    }
}

/// Is the hardware path usable right now? Probes by opening (and
/// immediately closing) a cycles counter.
pub fn available() -> bool {
    PmuGroup::open().is_some()
}

#[cfg(all(
    feature = "pmu",
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
))]
mod imp {
    use super::PmuCounters;
    use std::os::raw::{c_int, c_long, c_ulong, c_void};

    extern "C" {
        fn syscall(num: c_long, ...) -> c_long;
        fn ioctl(fd: c_int, request: c_ulong, ...) -> c_int;
        fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        fn close(fd: c_int) -> c_int;
    }

    #[cfg(target_arch = "x86_64")]
    const SYS_PERF_EVENT_OPEN: c_long = 298;
    #[cfg(target_arch = "aarch64")]
    const SYS_PERF_EVENT_OPEN: c_long = 241;

    const PERF_TYPE_HARDWARE: u32 = 0;
    const PERF_COUNT_HW_CPU_CYCLES: u64 = 0;
    const PERF_COUNT_HW_INSTRUCTIONS: u64 = 1;
    const PERF_COUNT_HW_CACHE_REFERENCES: u64 = 2;
    const PERF_COUNT_HW_CACHE_MISSES: u64 = 3;

    // Bit positions in the perf_event_attr flags word.
    const ATTR_DISABLED: u64 = 1 << 0;
    const ATTR_EXCLUDE_KERNEL: u64 = 1 << 5;
    const ATTR_EXCLUDE_HV: u64 = 1 << 6;

    const PERF_EVENT_IOC_ENABLE: c_ulong = 0x2400;
    const PERF_EVENT_IOC_DISABLE: c_ulong = 0x2401;
    const PERF_EVENT_IOC_RESET: c_ulong = 0x2403;

    /// `struct perf_event_attr` through PERF_ATTR_SIZE_VER5 (112 bytes).
    /// The kernel accepts any size it knows; trailing fields we never set
    /// must be zero. The C bitfield block is a single u64 here (`flags`).
    /// Fields are read by the kernel through the syscall pointer, never
    /// by Rust code.
    #[repr(C)]
    #[derive(Clone, Copy)]
    #[allow(dead_code)]
    struct PerfEventAttr {
        type_: u32,
        size: u32,
        config: u64,
        sample_period: u64,
        sample_type: u64,
        read_format: u64,
        flags: u64,
        wakeup_events: u32,
        bp_type: u32,
        config1: u64,
        config2: u64,
        branch_sample_type: u64,
        sample_regs_user: u64,
        sample_stack_user: u32,
        clockid: i32,
        sample_regs_intr: u64,
        aux_watermark: u32,
        sample_max_stack: u16,
        reserved_2: u16,
    }

    fn counting_attr(config: u64) -> PerfEventAttr {
        PerfEventAttr {
            type_: PERF_TYPE_HARDWARE,
            size: std::mem::size_of::<PerfEventAttr>() as u32,
            config,
            sample_period: 0,
            sample_type: 0,
            read_format: 0,
            flags: ATTR_DISABLED | ATTR_EXCLUDE_KERNEL | ATTR_EXCLUDE_HV,
            wakeup_events: 0,
            bp_type: 0,
            config1: 0,
            config2: 0,
            branch_sample_type: 0,
            sample_regs_user: 0,
            sample_stack_user: 0,
            clockid: 0,
            sample_regs_intr: 0,
            aux_watermark: 0,
            sample_max_stack: 0,
            reserved_2: 0,
        }
    }

    /// perf_event_open(attr, pid=0 (this thread), cpu=-1 (any), no group).
    fn open_counter(config: u64) -> Option<c_int> {
        let attr = counting_attr(config);
        // SAFETY: `attr` is a fully-initialized, correctly-sized struct
        // that outlives the call (the kernel copies it before returning);
        // the remaining arguments are plain integers. A refusing kernel
        // returns a negative fd, handled below — no UB on failure.
        let fd = unsafe {
            syscall(
                SYS_PERF_EVENT_OPEN,
                &attr as *const PerfEventAttr,
                0_i32,
                -1_i32,
                -1_i32,
                0_u64,
            )
        };
        if fd < 0 {
            None
        } else {
            Some(fd as c_int)
        }
    }

    /// The four counters, one fd each. Cycles is mandatory (`open`
    /// fails without it); the others are best-effort.
    pub struct PmuGroup {
        fds: [Option<c_int>; 4],
    }

    impl PmuGroup {
        pub fn open() -> Option<PmuGroup> {
            let cycles = open_counter(PERF_COUNT_HW_CPU_CYCLES)?;
            Some(PmuGroup {
                fds: [
                    Some(cycles),
                    open_counter(PERF_COUNT_HW_INSTRUCTIONS),
                    open_counter(PERF_COUNT_HW_CACHE_REFERENCES),
                    open_counter(PERF_COUNT_HW_CACHE_MISSES),
                ],
            })
        }

        /// Reset and start all available counters.
        pub fn start(&mut self) {
            for fd in self.fds.iter().flatten() {
                // SAFETY: fd is a live perf-event fd we opened (closed
                // only in Drop); these ioctls take no pointer arguments,
                // so the worst a bad request could do is return an error
                // we deliberately ignore (counter stays disabled).
                unsafe {
                    ioctl(*fd, PERF_EVENT_IOC_RESET, 0_i32);
                    ioctl(*fd, PERF_EVENT_IOC_ENABLE, 0_i32);
                }
            }
        }

        /// Stop all counters and read the accumulated values.
        pub fn stop_and_read(&mut self) -> PmuCounters {
            let mut vals = [0u64; 4];
            for (slot, fd) in self.fds.iter().enumerate() {
                let Some(fd) = fd else { continue };
                // SAFETY: live owned fd, no pointer argument (see start).
                unsafe {
                    ioctl(*fd, PERF_EVENT_IOC_DISABLE, 0_i32);
                }
                let mut v: u64 = 0;
                // SAFETY: reads at most 8 bytes into a valid, exclusive
                // 8-byte buffer (`&mut v`) that lives across the call.
                let n = unsafe { read(*fd, &mut v as *mut u64 as *mut c_void, 8) };
                if n == 8 {
                    vals[slot] = v;
                }
            }
            PmuCounters {
                cycles: vals[0],
                instructions: vals[1],
                cache_references: vals[2],
                cache_misses: vals[3],
            }
        }
    }

    impl Drop for PmuGroup {
        fn drop(&mut self) {
            for fd in self.fds.iter().flatten() {
                // SAFETY: each fd was opened by open_counter and is
                // closed exactly once, here.
                unsafe {
                    close(*fd);
                }
            }
        }
    }
}

#[cfg(not(all(
    feature = "pmu",
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
)))]
mod imp {
    use super::PmuCounters;

    /// Stub for builds without the `pmu` feature or on unsupported
    /// platforms: `open` always reports the hardware path unavailable.
    pub struct PmuGroup {
        _private: (),
    }

    impl PmuGroup {
        pub fn open() -> Option<PmuGroup> {
            None
        }

        pub fn start(&mut self) {}

        pub fn stop_and_read(&mut self) -> PmuCounters {
            PmuCounters::default()
        }
    }
}

pub use imp::PmuGroup;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_sum_phases_and_iters() {
        let m = PmuMetrics {
            phases: vec![(
                "load".to_string(),
                PmuCounters {
                    cycles: 10,
                    instructions: 20,
                    cache_references: 8,
                    cache_misses: 2,
                },
            )],
            iters: vec![
                PmuCounters {
                    cycles: 5,
                    instructions: 5,
                    cache_references: 2,
                    cache_misses: 2,
                },
                PmuCounters::default(),
            ],
        };
        let t = m.total();
        assert_eq!(t.cycles, 15);
        assert_eq!(t.instructions, 25);
        assert_eq!(t.cache_references, 10);
        assert_eq!(t.cache_misses, 4);
        assert_eq!(t.llc_miss_rate(), Some(0.4));
        assert_eq!(PmuCounters::default().llc_miss_rate(), None);
    }

    #[test]
    fn open_probe_is_graceful_and_reads_are_sane() {
        // In sandboxes/CI `perf_event_open` is typically blocked; the
        // contract is: no panic, `None` when unavailable, plausible
        // counts when available.
        match PmuGroup::open() {
            None => assert!(!available()),
            Some(mut g) => {
                g.start();
                let mut acc = 0u64;
                for i in 0..100_000u64 {
                    acc = acc.wrapping_add(i * i);
                }
                std::hint::black_box(acc);
                let c = g.stop_and_read();
                // Cycles is the mandatory counter; if the fd opened, a
                // 100k-iteration loop must consume some cycles.
                assert!(c.cycles > 0, "opened PMU but read zero cycles");
            }
        }
    }
}
