//! Chrome `trace_event` export for run-report timelines.
//!
//! Converts a [`RunReport`]'s event timeline into the JSON format that
//! `chrome://tracing` and Perfetto render as a flamegraph: one complete
//! (`"ph": "X"`) event per recorder span, timestamps and durations in
//! microseconds, kind-specific counters under `args` with readable names
//! instead of the schema's generic `a..d`.

use crate::obs::report::{RunReport, TimelineEvent};
use crate::util::json::Value;

/// Render a report's timeline as a Chrome `trace_event` JSON document
/// (trailing newline included).
pub fn chrome_trace(report: &RunReport) -> String {
    let events: Vec<Value> = report.events.iter().map(event_to_value).collect();
    let doc = Value::Obj(vec![
        ("traceEvents".to_string(), Value::Arr(events)),
        ("displayTimeUnit".to_string(), Value::Str("ms".to_string())),
        (
            "otherData".to_string(),
            Value::Obj(vec![
                ("app".to_string(), Value::Str(report.app.clone())),
                ("dataset".to_string(), Value::Str(report.dataset.clone())),
                ("git_sha".to_string(), Value::Str(report.git_sha.clone())),
                (
                    "stall_source".to_string(),
                    Value::Str(report.stall_source().to_string()),
                ),
            ]),
        ),
    ]);
    let mut out = doc.render();
    out.push('\n');
    out
}

fn event_to_value(ev: &TimelineEvent) -> Value {
    Value::Obj(vec![
        ("name".to_string(), Value::Str(ev.name.clone())),
        ("cat".to_string(), Value::Str(ev.kind.clone())),
        ("ph".to_string(), Value::Str("X".to_string())),
        ("ts".to_string(), Value::Num(ev.t_us as f64)),
        ("dur".to_string(), Value::Num(ev.dur_us as f64)),
        ("pid".to_string(), Value::Num(1.0)),
        ("tid".to_string(), Value::Num(1.0)),
        ("args".to_string(), Value::Obj(event_args(ev))),
    ])
}

/// Kind-specific counter names (mirrors `recorder::EventKind` docs).
fn event_args(ev: &TimelineEvent) -> Vec<(String, Value)> {
    let num = |n: u64| Value::Num(n as f64);
    match ev.kind.as_str() {
        "edge_map" => vec![
            ("frontier".to_string(), num(ev.a)),
            ("out_work".to_string(), num(ev.b)),
            ("next_frontier".to_string(), num(ev.c)),
            (
                "direction".to_string(),
                Value::Str(if ev.d == 1 { "dense/pull" } else { "sparse/push" }.to_string()),
            ),
        ],
        "segment" => vec![
            ("segment".to_string(), num(ev.a)),
            ("edges".to_string(), num(ev.b)),
            ("buffer_bytes".to_string(), num(ev.c)),
        ],
        "iter" => vec![
            ("index".to_string(), num(ev.a)),
            ("source".to_string(), num(ev.b)),
        ],
        "artifact" => vec![(
            "outcome".to_string(),
            Value::Str(if ev.a == 1 { "hit" } else { "build" }.to_string()),
        )],
        _ => Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn export_is_well_formed_trace_event_json() {
        let report = crate::obs::report::sample_report();
        let text = chrome_trace(&report);
        let doc = json::parse(&text).expect("chrome trace must be valid JSON");
        let events = doc
            .get("traceEvents")
            .and_then(Value::as_arr)
            .expect("traceEvents array");
        assert_eq!(events.len(), report.events.len());
        for ev in events {
            assert_eq!(ev.get("ph").and_then(Value::as_str), Some("X"));
            assert!(ev.get("ts").and_then(Value::as_f64).is_some());
            assert!(ev.get("dur").and_then(Value::as_f64).is_some());
            assert!(ev.get("name").and_then(Value::as_str).is_some());
        }
        // The edge_map span carries readable direction args.
        let em = &events[1];
        let args = em.get("args").expect("args");
        assert_eq!(
            args.get("direction").and_then(Value::as_str),
            Some("dense/pull")
        );
        assert_eq!(args.get("frontier").and_then(Value::as_f64), Some(10.0));
    }
}
