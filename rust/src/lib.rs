//! # Cagra-RS
//!
//! A cache-optimized graph analytics framework reproducing **"Making Caches
//! Work for Graph Analytics"** (Zhang, Kiriansky, Mendis, Zaharia,
//! Amarasinghe, 2016). The paper's two techniques — **vertex reordering**
//! (§3) and **CSR segmenting** (§4) — are implemented as first-class
//! preprocessing passes over a Ligra-style shared-memory engine, together
//! with every substrate the evaluation depends on: graph generators, a
//! multi-level cache simulator, the analytical cache model (§5), baseline
//! frameworks (GraphMat/Ligra/GridGraph/X-Stream/Hilbert styles), and a
//! PJRT runtime that executes JAX/Pallas-authored AOT artifacts for the
//! numeric hot path.
//!
//! ## Layering
//!
//! - **L3 (this crate)** — coordination: preprocessing, segment-at-a-time
//!   scheduling, cache-aware merge, thread pool, metrics, CLI. Workloads
//!   implement the [`apps::GraphApp`] trait and register in
//!   [`apps::registry`]; the coordinator's `run_job` drives every app —
//!   the full §6.1 suite of eight — through one generic
//!   prepare → execute → summarize loop, so the cache techniques (and the
//!   store, and the memory simulator) plug in at the framework level
//!   instead of per app. The [`store`] subsystem persists preprocessing
//!   outputs (permutations, relabeled CSRs, segmented partitions) in a
//!   fingerprint-keyed on-disk cache so their cost is amortized across
//!   runs (paper Table 9).
//! - **L2 (python/compile/model.py)** — PageRank / Collaborative-Filtering
//!   steps over dense segment tiles, lowered once to HLO text.
//! - **L1 (python/compile/kernels/)** — Pallas tile kernels
//!   (`interpret=True`), validated against pure-jnp oracles.
//!
//! Python never runs on the request path; [`runtime`] loads the artifacts
//! via the PJRT C API.

pub mod util;
pub mod audit;
pub mod fault;
pub mod parallel;
pub mod graph;
pub mod reorder;
pub mod segment;
pub mod store;
pub mod cache;
pub mod engine;
pub mod apps;
pub mod baselines;
pub mod runtime;
pub mod coordinator;
pub mod serve;
pub mod bench;
pub mod obs;
