//! PJRT runtime: loads the HLO-text artifacts that `python/compile/aot.py`
//! produced at build time and executes them on the CPU PJRT client.
//! Python is **never** on this path — the artifacts are plain files.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The PJRT client itself comes from the external `xla` crate, which the
//! offline build image does not ship; it is gated behind the **`pjrt`
//! cargo feature** (see Cargo.toml). Without the feature this module
//! compiles a same-shape stub: artifact scanning and metadata still work,
//! `available()` reports nothing, and `load`/`run_f32` return a clear
//! error — so the CLI, examples, and `tests/pjrt_integration.rs` (which
//! already skips when no artifacts are loadable) degrade gracefully.

pub mod artifacts;

pub use artifacts::{ArtifactMeta, Artifacts};

use anyhow::Result;
use std::path::Path;

#[cfg(feature = "pjrt")]
use anyhow::Context;
#[cfg(feature = "pjrt")]
use std::collections::HashMap;

/// A loaded, compiled XLA executable plus its metadata.
#[cfg(feature = "pjrt")]
pub struct Executable {
    pub name: String,
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute on f32 buffers. Each input is (data, dims); the single
    /// tuple output is flattened to a Vec<f32> per element.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims_i64)
                .context("reshaping input literal")?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .context("executing PJRT artifact")?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True.
        let elems = result.to_tuple().context("untupling result")?;
        let mut out = Vec::with_capacity(elems.len());
        for e in elems {
            out.push(e.to_vec::<f32>().context("reading f32 output")?);
        }
        Ok(out)
    }
}

/// The runtime: a PJRT CPU client plus a cache of compiled executables.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<String, Executable>,
    artifacts: Artifacts,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create against an artifacts directory (default `artifacts/`).
    pub fn new(dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let artifacts = Artifacts::scan(dir)?;
        Ok(Runtime {
            client,
            cache: HashMap::new(),
            artifacts,
        })
    }

    /// Default artifacts dir: `$CAGRA_ARTIFACTS` or `artifacts/`.
    pub fn from_env() -> Result<Runtime> {
        let dir = std::env::var("CAGRA_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::new(dir)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn available(&self) -> Vec<&str> {
        self.artifacts.names()
    }

    /// Load (compile-once, cached) an artifact by name.
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let (path, meta) = self.artifacts.get(name)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            self.cache.insert(
                name.to_string(),
                Executable {
                    name: name.to_string(),
                    meta,
                    exe,
                },
            );
        }
        Ok(&self.cache[name])
    }
}

/// Stub executable (built without the `pjrt` feature).
#[cfg(not(feature = "pjrt"))]
pub struct Executable {
    pub name: String,
    pub meta: ArtifactMeta,
}

#[cfg(not(feature = "pjrt"))]
impl Executable {
    pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        anyhow::bail!(
            "cagra was built without the `pjrt` feature; rebuild with \
             `--features pjrt` (requires the external `xla` crate) to \
             execute AOT artifacts"
        )
    }
}

/// Stub runtime (built without the `pjrt` feature): artifact scanning and
/// metadata parsing still work, but nothing is loadable.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    artifacts: Artifacts,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn new(dir: impl AsRef<Path>) -> Result<Runtime> {
        Ok(Runtime {
            artifacts: Artifacts::scan(dir)?,
        })
    }

    pub fn from_env() -> Result<Runtime> {
        let dir = std::env::var("CAGRA_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::new(dir)
    }

    pub fn platform(&self) -> String {
        "stub (pjrt feature disabled)".to_string()
    }

    /// Nothing is loadable without the PJRT client, so report no
    /// artifacts — callers (CLI `artifacts`, integration tests) already
    /// handle the empty case by skipping.
    pub fn available(&self) -> Vec<&str> {
        Vec::new()
    }

    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        let _ = self.artifacts.get(name); // surface scan-path errors in logs someday
        anyhow::bail!(
            "cannot load artifact {name:?}: cagra was built without the \
             `pjrt` feature (rebuild with `--features pjrt`)"
        )
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    // Runtime integration tests (needing built artifacts) live in
    // rust/tests/pjrt_integration.rs; here we only check client creation,
    // which requires no artifacts.
    #[test]
    fn cpu_client_comes_up() {
        let c = xla::PjRtClient::cpu().expect("PJRT CPU client");
        assert_eq!(c.platform_name(), "cpu");
        assert!(c.device_count() >= 1);
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_runtime_scans_but_loads_nothing() {
        let dir = std::env::temp_dir().join(format!("cagra-rt-stub-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("m.hlo.txt"), "HloModule m").unwrap();
        let mut rt = Runtime::new(&dir).unwrap();
        assert!(rt.platform().contains("stub"));
        assert!(rt.available().is_empty());
        assert!(rt.load("m").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
