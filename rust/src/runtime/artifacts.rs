//! Artifact registry: scans `artifacts/` for `<name>.hlo.txt` plus the
//! sidecar `<name>.meta` describing shapes (written by aot.py, parsed with
//! the in-repo config parser — no serde offline).

use crate::util::config::Config;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape metadata for one artifact.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ArtifactMeta {
    /// Input dims, in argument order.
    pub inputs: Vec<Vec<usize>>,
    /// Output dims, in tuple order.
    pub outputs: Vec<Vec<usize>>,
    /// Free-form key/values from the meta file (e.g. tile=256, k=8).
    pub params: BTreeMap<String, String>,
}

impl ArtifactMeta {
    /// Parse the `.meta` sidecar:
    /// ```text
    /// [shapes]
    /// input0 = 8x256
    /// output0 = 256
    /// [params]
    /// tile = 256
    /// ```
    pub fn parse(text: &str) -> Result<ArtifactMeta> {
        let cfg = Config::parse(text)?;
        let mut meta = ArtifactMeta::default();
        let parse_dims = |s: &str| -> Result<Vec<usize>> {
            if s.trim().is_empty() || s.trim() == "scalar" {
                return Ok(vec![]);
            }
            s.split('x')
                .map(|t| t.trim().parse::<usize>().context("bad dim"))
                .collect()
        };
        for i in 0.. {
            match cfg.get(&format!("shapes.input{i}")) {
                Some(s) => meta.inputs.push(parse_dims(s)?),
                None => break,
            }
        }
        for i in 0.. {
            match cfg.get(&format!("shapes.output{i}")) {
                Some(s) => meta.outputs.push(parse_dims(s)?),
                None => break,
            }
        }
        for k in cfg.keys() {
            if let Some(name) = k.strip_prefix("params.") {
                meta.params.insert(name.to_string(), cfg.get(k).unwrap().to_string());
            }
        }
        Ok(meta)
    }

    pub fn param_usize(&self, key: &str) -> Result<usize> {
        self.params
            .get(key)
            .with_context(|| format!("meta param {key:?} missing"))?
            .parse()
            .with_context(|| format!("meta param {key:?} not an integer"))
    }
}

/// Directory scan of available artifacts.
pub struct Artifacts {
    dir: PathBuf,
    names: Vec<String>,
}

impl Artifacts {
    pub fn scan(dir: impl AsRef<Path>) -> Result<Artifacts> {
        let dir = dir.as_ref().to_path_buf();
        let mut names = Vec::new();
        if dir.is_dir() {
            for entry in std::fs::read_dir(&dir)? {
                let p = entry?.path();
                if let Some(fname) = p.file_name().and_then(|f| f.to_str()) {
                    if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                        names.push(stem.to_string());
                    }
                }
            }
        }
        names.sort();
        Ok(Artifacts { dir, names })
    }

    pub fn names(&self) -> Vec<&str> {
        self.names.iter().map(|s| s.as_str()).collect()
    }

    /// Resolve an artifact to (hlo path, parsed meta).
    pub fn get(&self, name: &str) -> Result<(PathBuf, ArtifactMeta)> {
        if !self.names.iter().any(|n| n == name) {
            bail!(
                "artifact {name:?} not found in {} (have: {:?}); run `make artifacts`",
                self.dir.display(),
                self.names
            );
        }
        let hlo = self.dir.join(format!("{name}.hlo.txt"));
        let meta_path = self.dir.join(format!("{name}.meta"));
        let meta = if meta_path.is_file() {
            ArtifactMeta::parse(&std::fs::read_to_string(&meta_path)?)
                .with_context(|| format!("parsing {}", meta_path.display()))?
        } else {
            ArtifactMeta::default()
        };
        Ok((hlo, meta))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parses_shapes_and_params() {
        let m = ArtifactMeta::parse(
            "[shapes]\ninput0 = 8x256x256\ninput1 = 256\noutput0 = 256\n[params]\ntile = 256\nk = 8\n",
        )
        .unwrap();
        assert_eq!(m.inputs, vec![vec![8, 256, 256], vec![256]]);
        assert_eq!(m.outputs, vec![vec![256]]);
        assert_eq!(m.param_usize("tile").unwrap(), 256);
        assert!(m.param_usize("missing").is_err());
    }

    #[test]
    fn scalar_dims() {
        let m = ArtifactMeta::parse("[shapes]\ninput0 = scalar\noutput0 = 4\n").unwrap();
        assert_eq!(m.inputs, vec![Vec::<usize>::new()]);
    }

    #[test]
    fn scan_missing_dir_is_empty() {
        let a = Artifacts::scan("/definitely/not/a/dir").unwrap();
        assert!(a.names().is_empty());
        assert!(a.get("x").is_err());
    }

    #[test]
    fn scan_finds_artifacts() {
        let dir = std::env::temp_dir().join(format!("cagra-art-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("m.hlo.txt"), "HloModule m").unwrap();
        std::fs::write(dir.join("m.meta"), "[shapes]\ninput0 = 2x2\n").unwrap();
        let a = Artifacts::scan(&dir).unwrap();
        assert_eq!(a.names(), vec!["m"]);
        let (p, meta) = a.get("m").unwrap();
        assert!(p.ends_with("m.hlo.txt"));
        assert_eq!(meta.inputs, vec![vec![2, 2]]);
        std::fs::remove_dir_all(dir).ok();
    }
}
