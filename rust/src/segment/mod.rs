//! CSR segmenting (paper §4).
//!
//! Preprocess the graph so that the randomly-accessed *source* vertex data
//! is processed one cache-sized **segment** at a time:
//!
//! 1. **Preprocessing** (§4.1, [`SegmentedCsr::build`]): divide vertices
//!    into segments of `seg_size` ids; for each segment collect the edges
//!    whose **source** lies in the segment, grouped by destination into a
//!    local CSR over that segment's *adjacent* (destination) vertices,
//!    plus an index vector mapping local → global destination ids.
//! 2. **Segment processing** (§4.2, [`SegmentedCsr::process_segment`]):
//!    within a segment all threads share the same read-only working set
//!    (the segment's slice of source data) — random reads stay in cache,
//!    no atomics needed because each local destination is written by one
//!    task.
//! 3. **Cache-aware merge** (§4.3, [`merge`]): combine the per-segment
//!    sparse intermediate vectors into the dense output, processing
//!    L1-cache-sized blocks of the vertex-id range in parallel with only
//!    sequential reads — a precomputed [`MergePlan`] holds each block's
//!    start/end cursor in every segment's index vector, so the inner loop
//!    is branch-light.

pub mod merge;
pub mod expansion;

pub use expansion::expansion_factor;
pub use merge::{merge, merge_serial, MergePlan};

use crate::graph::{Csr, VertexId};
use crate::parallel::{parallel_for, parallel_for_cost, UnsafeSlice};
use crate::store::ArcSlice;
use crate::util::ceil_div;

/// One subgraph: the edges whose sources fall in `[src_lo, src_hi)`,
/// indexed by destination (Figure 5's per-segment structure). Arrays are
/// [`ArcSlice`]s — heap-owned when built, mmap-backed when warm-loaded
/// from a v2 artifact (DESIGN.md §6).
#[derive(Debug, Clone)]
pub struct Segment {
    /// Source-vertex range covered by this segment.
    pub src_lo: VertexId,
    pub src_hi: VertexId,
    /// Global ids of destinations adjacent to this segment, ascending —
    /// §4.1 step 3's "index vector" used by the merge phase.
    pub dst_ids: ArcSlice<VertexId>,
    /// Local CSR: `offsets[i]..offsets[i+1]` are the edges into
    /// `dst_ids[i]`.
    pub offsets: ArcSlice<u64>,
    /// Edge sources (global ids within `[src_lo, src_hi)`).
    pub sources: ArcSlice<VertexId>,
}

impl Segment {
    pub fn num_dsts(&self) -> usize {
        self.dst_ids.len()
    }

    pub fn num_edges(&self) -> usize {
        self.sources.len()
    }
}

/// The segmented graph: all subgraphs plus the merge plan.
#[derive(Debug, Clone)]
pub struct SegmentedCsr {
    pub num_vertices: usize,
    pub seg_size: usize,
    pub segments: Vec<Segment>,
    pub merge_plan: MergePlan,
}

impl SegmentedCsr {
    /// Preprocess `g` (out-edge CSR) into source-segments of `seg_size`
    /// vertices. `seg_size` is chosen so `seg_size * bytes_per_vertex`
    /// fits the (effective) LLC — see
    /// [`crate::coordinator::SystemConfig::segment_size`].
    pub fn build(g: &Csr, seg_size: usize) -> SegmentedCsr {
        Self::build_with_block(g, seg_size, MergePlan::DEFAULT_BLOCK)
    }

    /// Build with an explicit merge block size (vertex ids per L1 block).
    pub fn build_with_block(g: &Csr, seg_size: usize, merge_block: usize) -> SegmentedCsr {
        let n = g.num_vertices();
        let seg_size = seg_size.max(1);
        let k = ceil_div(n.max(1), seg_size);
        // Pass 1: count edges per segment (segment of an edge = its
        // source's segment).
        let mut seg_edge_counts = vec![0u64; k];
        for v in 0..n {
            let s = v / seg_size;
            seg_edge_counts[s] += g.degree(v as VertexId) as u64;
        }
        // Build each segment independently (parallel over segments —
        // "this preprocessing phase can be done in parallel, by building
        // each segment separately from the original CSR", §4.1).
        let mut segments: Vec<Segment> = Vec::with_capacity(k);
        for s in 0..k {
            segments.push(Segment {
                src_lo: (s * seg_size) as VertexId,
                src_hi: ((s + 1) * seg_size).min(n) as VertexId,
                dst_ids: ArcSlice::default(),
                offsets: ArcSlice::default(),
                sources: ArcSlice::default(),
            });
        }
        {
            let seg_slice = UnsafeSlice::new(&mut segments);
            parallel_for(k, |s| {
                // SAFETY: each loop index s writes only its own element,
                // and s < k == segments.len().
                let seg = unsafe { seg_slice.get_mut(s) };
                build_segment(g, seg, seg_edge_counts[s] as usize);
            });
        }
        let merge_plan = MergePlan::build(n, merge_block, &segments);
        SegmentedCsr {
            num_vertices: n,
            seg_size,
            segments,
            merge_plan,
        }
    }

    pub fn num_segments(&self) -> usize {
        self.segments.len()
    }

    /// Total edges across all segments (== original edge count).
    pub fn num_edges(&self) -> usize {
        self.segments.iter().map(|s| s.num_edges()).sum()
    }

    /// Sum over segments of adjacent-destination counts — the merge
    /// phase's total sequential traffic, `q·V` in Table 10.
    pub fn total_adjacent(&self) -> usize {
        self.segments.iter().map(|s| s.num_dsts()).sum()
    }

    /// Process one segment (§4.2): for each local destination `i`,
    /// aggregate `contrib(source)` over the segment's edges into
    /// `out[i]` (the segment's intermediate vector, `len == num_dsts`).
    ///
    /// Parallelized over destinations with the cost-based scheduler so the
    /// degree-sorted head does not imbalance threads (§3.2). All threads
    /// read the same `[src_lo, src_hi)` slice of source data — the shared
    /// cache-resident working set that makes segmenting scale (§4.2).
    // audit: hot-path — per-segment sweeps + aggregate driver, once per
    // iteration per segment; buffers are caller-provided (hot-path-alloc
    // lint enforces no fresh allocation through the end marker).
    pub fn process_segment<F>(&self, seg_idx: usize, contrib: F, out: &mut [f64])
    where
        F: Fn(VertexId) -> f64 + Sync,
    {
        let seg = &self.segments[seg_idx];
        assert_eq!(out.len(), seg.num_dsts());
        let out_slice = UnsafeSlice::new(out);
        let nd = seg.num_dsts();
        // Cost = edges in the destination range; threshold keeps ~4 tasks
        // per thread worth of work.
        let total = seg.num_edges() as u64;
        let threshold = (total / (4 * crate::parallel::num_threads() as u64).max(1)).max(256);
        parallel_for_cost(
            nd,
            threshold,
            |lo, hi| seg.offsets[hi] - seg.offsets[lo],
            |lo, hi| {
                for i in lo..hi {
                    let e0 = seg.offsets[i] as usize;
                    let e1 = seg.offsets[i + 1] as usize;
                    let mut acc = 0.0f64;
                    for &u in &seg.sources[e0..e1] {
                        acc += contrib(u);
                    }
                    // SAFETY: each local destination i is handed to
                    // exactly one task and i < nd == out.len().
                    unsafe { out_slice.write(i, acc) };
                }
            },
        );
    }

    /// Specialized hot path for the dominant case (PageRank-style f64
    /// contribution array): bounds checks lifted out of the inner loop.
    /// ~15% of iteration time on the profile (§Perf change 1).
    pub fn process_segment_slice(&self, seg_idx: usize, contrib: &[f64], out: &mut [f64]) {
        let seg = &self.segments[seg_idx];
        assert_eq!(out.len(), seg.num_dsts());
        assert!(contrib.len() >= self.num_vertices);
        let out_slice = UnsafeSlice::new(out);
        let nd = seg.num_dsts();
        let total = seg.num_edges() as u64;
        let threshold = (total / (4 * crate::parallel::num_threads() as u64).max(1)).max(256);
        parallel_for_cost(
            nd,
            threshold,
            |lo, hi| seg.offsets[hi] - seg.offsets[lo],
            |lo, hi| {
                for i in lo..hi {
                    let e0 = seg.offsets[i] as usize;
                    let e1 = seg.offsets[i + 1] as usize;
                    // SAFETY: sources are < num_vertices ≤ contrib.len()
                    // by construction (asserted above), edge ranges
                    // e0..e1 are within seg.sources, and each local
                    // destination i is handed to exactly one task with
                    // i < nd == out.len().
                    // 4 accumulators break the serial FP-add dependency
                    // chain (~4 cyc/edge -> ~1 cyc/edge on high-degree
                    // destinations; §Perf change 3).
                    unsafe {
                        let src = seg.sources.get_unchecked(e0..e1);
                        let mut a0 = 0.0f64;
                        let mut a1 = 0.0f64;
                        let mut a2 = 0.0f64;
                        let mut a3 = 0.0f64;
                        let chunks = src.len() / 4;
                        // NOTE §Perf change 4 (software prefetch of the
                        // contrib lines) was tried and REVERTED: -13% —
                        // the segment working set is already L2-resident,
                        // so the extra prefetch µops cost more than they
                        // hide.
                        for c in 0..chunks {
                            let b = c * 4;
                            a0 += *contrib.get_unchecked(*src.get_unchecked(b) as usize);
                            a1 += *contrib.get_unchecked(*src.get_unchecked(b + 1) as usize);
                            a2 += *contrib.get_unchecked(*src.get_unchecked(b + 2) as usize);
                            a3 += *contrib.get_unchecked(*src.get_unchecked(b + 3) as usize);
                        }
                        for k in chunks * 4..src.len() {
                            a0 += *contrib.get_unchecked(*src.get_unchecked(k) as usize);
                        }
                        out_slice.write(i, (a0 + a1) + (a2 + a3));
                    }
                }
            },
        );
    }

    /// Run the full segmented aggregation: process every segment in turn
    /// into `buffers`, then cache-aware-merge into `out` (dense, len ==
    /// num_vertices). `init` seeds each output cell before merging.
    pub fn aggregate<F>(&self, contrib: F, buffers: &mut SegmentBuffers, init: f64, out: &mut [f64])
    where
        F: Fn(VertexId) -> f64 + Sync,
    {
        assert_eq!(out.len(), self.num_vertices);
        for s in 0..self.num_segments() {
            let t0 = crate::obs::recorder::timestamp();
            self.process_segment(s, &contrib, &mut buffers.per_segment[s]);
            crate::obs::recorder::record_segment(
                t0,
                s as u64,
                self.segments[s].num_edges() as u64,
                (buffers.per_segment[s].len() * 8) as u64,
            );
        }
        let t_merge = crate::obs::recorder::timestamp();
        out.fill(init);
        merge(self, buffers, out);
        crate::obs::recorder::record_merge(t_merge);
    }
    // audit: hot-path-end

    /// Bytes of auxiliary structure (for preprocessing-cost reports).
    pub fn bytes(&self) -> usize {
        self.segments
            .iter()
            .map(|s| s.dst_ids.len() * 4 + s.offsets.len() * 8 + s.sources.len() * 4)
            .sum::<usize>()
            + self.merge_plan.bytes()
    }
}

/// Build one segment's local CSR from the parent graph.
fn build_segment(g: &Csr, seg: &mut Segment, edge_count_hint: usize) {
    // Collect (dst, src) pairs for sources in [src_lo, src_hi).
    let mut pairs: Vec<(VertexId, VertexId)> = Vec::with_capacity(edge_count_hint);
    for u in seg.src_lo..seg.src_hi {
        for &v in g.neighbors(u) {
            pairs.push((v, u));
        }
    }
    // Group by destination: sort by (dst, src-order preserved by stable
    // sort on dst only).
    pairs.sort_unstable();
    let mut dst_ids = Vec::new();
    let mut offsets: Vec<u64> = Vec::new();
    let mut sources = Vec::with_capacity(pairs.len());
    let mut last_dst: Option<VertexId> = None;
    for (v, u) in pairs {
        if last_dst != Some(v) {
            dst_ids.push(v);
            offsets.push(sources.len() as u64);
            last_dst = Some(v);
        }
        sources.push(u);
    }
    offsets.push(sources.len() as u64);
    seg.dst_ids = dst_ids.into();
    seg.offsets = offsets.into();
    seg.sources = sources.into();
}

/// Reusable per-segment intermediate vectors ("Create an array to hold the
/// intermediate result for each adjacent vertex", §4.1 step 2). Allocated
/// once, reused every iteration — generic so the same reuse discipline
/// covers every [`crate::engine::segmented_edge_map`] element type (CC's
/// `u32` labels, counts, ...), not just the f64 PageRank/CF path.
/// Contents are dead between calls: every aggregation pass fully rewrites
/// each entry before the merge reads it, so no clearing is ever needed.
#[derive(Debug, Clone)]
pub struct SegmentBuffers<T = f64> {
    pub per_segment: Vec<Vec<T>>,
}

impl<T: Copy> SegmentBuffers<T> {
    /// Buffers sized for `sg`, seeded with `fill` (the seed value is
    /// irrelevant to correctness — see the type docs).
    pub fn with_fill(sg: &SegmentedCsr, fill: T) -> SegmentBuffers<T> {
        SegmentBuffers {
            per_segment: sg
                .segments
                .iter()
                .map(|s| vec![fill; s.num_dsts()])
                .collect(),
        }
    }

    /// Bytes held (for scratch-footprint metrics).
    pub fn bytes(&self) -> usize {
        self.per_segment
            .iter()
            .map(|v| v.len() * std::mem::size_of::<T>())
            .sum()
    }
}

impl SegmentBuffers<f64> {
    pub fn for_graph(sg: &SegmentedCsr) -> SegmentBuffers<f64> {
        SegmentBuffers::with_fill(sg, 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::prop::check;

    /// The Figure 5 example: vertices 0..6 split into {0,1,2} and {3,4,5}.
    fn fig5() -> Csr {
        // Edges chosen so segment 1 (sources 0-2) reaches dsts {0,1,2,5}
        // and segment 2 (sources 3-5) reaches dsts {0,3,4,5}.
        Csr::from_edges(
            6,
            &[
                (0, 1),
                (1, 0),
                (1, 2),
                (2, 5),
                (2, 0),
                (3, 0),
                (3, 4),
                (4, 5),
                (5, 3),
                (5, 5),
            ],
        )
    }

    #[test]
    fn fig5_structure() {
        let g = fig5();
        let sg = SegmentedCsr::build(&g, 3);
        assert_eq!(sg.num_segments(), 2);
        assert_eq!(sg.segments[0].dst_ids, vec![0, 1, 2, 5]);
        assert_eq!(sg.segments[1].dst_ids, vec![0, 3, 4, 5]);
        assert_eq!(sg.num_edges(), g.num_edges());
    }

    #[test]
    fn edges_partitioned_exactly_once() {
        let g = fig5();
        let sg = SegmentedCsr::build(&g, 3);
        let mut seen: Vec<(VertexId, VertexId)> = Vec::new();
        for seg in &sg.segments {
            for (i, &d) in seg.dst_ids.iter().enumerate() {
                for &u in &seg.sources[seg.offsets[i] as usize..seg.offsets[i + 1] as usize] {
                    assert!((seg.src_lo..seg.src_hi).contains(&u));
                    seen.push((u, d));
                }
            }
        }
        seen.sort_unstable();
        let mut orig: Vec<_> = g.edges().collect();
        orig.sort_unstable();
        assert_eq!(seen, orig);
    }

    #[test]
    fn aggregate_equals_direct() {
        let (n, edges) = generators::rmat(10, 8, generators::RmatParams::graph500(), 42);
        let g = Csr::from_edges(n, &edges);
        let vals: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        // Direct pull aggregation over the transpose.
        let t = g.transpose();
        let mut direct = vec![0.25f64; n];
        for v in 0..n {
            for &u in t.neighbors(v as VertexId) {
                direct[v] += vals[u as usize];
            }
        }
        // Segmented.
        let sg = SegmentedCsr::build(&g, 100);
        let mut bufs = SegmentBuffers::for_graph(&sg);
        let mut out = vec![0.0; n];
        sg.aggregate(|u| vals[u as usize], &mut bufs, 0.25, &mut out);
        for v in 0..n {
            assert!(
                (out[v] - direct[v]).abs() <= 1e-9 * direct[v].abs().max(1.0),
                "v={v}: {} vs {}",
                out[v],
                direct[v]
            );
        }
    }

    #[test]
    fn single_segment_degenerates_gracefully() {
        let g = fig5();
        let sg = SegmentedCsr::build(&g, 1000);
        assert_eq!(sg.num_segments(), 1);
        let mut bufs = SegmentBuffers::for_graph(&sg);
        let mut out = vec![0.0; 6];
        sg.aggregate(|_| 1.0, &mut bufs, 0.0, &mut out);
        // out[v] == in-degree(v).
        let indeg = g.in_degrees();
        for v in 0..6 {
            assert_eq!(out[v], indeg[v] as f64);
        }
    }

    #[test]
    fn seg_size_one_extreme() {
        let g = fig5();
        let sg = SegmentedCsr::build(&g, 1);
        assert_eq!(sg.num_segments(), 6);
        let mut bufs = SegmentBuffers::for_graph(&sg);
        let mut out = vec![0.0; 6];
        sg.aggregate(|_| 1.0, &mut bufs, 0.0, &mut out);
        let indeg = g.in_degrees();
        for v in 0..6 {
            assert_eq!(out[v], indeg[v] as f64);
        }
    }

    #[test]
    fn prop_segmented_aggregation_matches_direct() {
        check("segmented == direct aggregation", 15, |gen| {
            let (n, edges) = gen.edges(2..150, 5);
            let g = Csr::from_edges(n, &edges);
            let seg_size = gen.usize(1..n + 1);
            let block = [8usize, 16, 64, 1024][gen.usize(0..4)];
            let sg = SegmentedCsr::build_with_block(&g, seg_size, block);
            assert_eq!(sg.num_edges(), g.num_edges());
            let vals: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
            let t = g.transpose();
            let mut direct = vec![0.0f64; n];
            for v in 0..n {
                for &u in t.neighbors(v as VertexId) {
                    direct[v] += vals[u as usize];
                }
            }
            let mut bufs = SegmentBuffers::for_graph(&sg);
            let mut out = vec![0.0; n];
            sg.aggregate(|u| vals[u as usize], &mut bufs, 0.0, &mut out);
            // Integer-valued sums: exact equality expected.
            assert_eq!(out, direct);
        });
    }
}
