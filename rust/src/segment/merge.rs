//! Cache-aware merge (paper §4.3).
//!
//! After the per-segment passes, each segment holds a *sparse* vector of
//! updates (values aligned with its ascending `dst_ids`). The merge
//! combines them into the dense output by walking **L1-cache-sized blocks
//! of the vertex-id range**: for each block, every segment's entries in
//! that id range are read sequentially and accumulated into the dense
//! output slice, which stays L1-resident. A precomputed [`MergePlan`]
//! ("a helper data structure holds the start and end index of each output
//! block's vertex IDs in each of the per-segment vectors") removes all
//! searching from the hot loop; blocks are distributed over threads with
//! the work-stealing scheduler.

use super::{SegmentBuffers, Segment, SegmentedCsr};
use crate::parallel::{parallel_for_cost, UnsafeSlice};
use crate::util::ceil_div;

/// Per-block cursors into every segment's `dst_ids`.
#[derive(Debug, Clone)]
pub struct MergePlan {
    /// Vertex ids per block. Default sized so a block of f64 output
    /// (+ the incoming entries) fits L1: 4096 ids = 32 KiB of output.
    pub block_size: usize,
    pub num_blocks: usize,
    /// `starts[seg][b]` = first index in segment `seg`'s dst_ids whose id
    /// is >= b*block_size; length num_blocks+1 per segment.
    pub starts: Vec<Vec<u32>>,
}

impl MergePlan {
    /// 4096 × 8 B = 32 KiB of dense output per block (typical L1d).
    pub const DEFAULT_BLOCK: usize = 4096;

    pub fn build(num_vertices: usize, block_size: usize, segments: &[Segment]) -> MergePlan {
        let block_size = block_size.max(1);
        let num_blocks = ceil_div(num_vertices.max(1), block_size);
        let starts = segments
            .iter()
            .map(|seg| {
                let mut cur = Vec::with_capacity(num_blocks + 1);
                let mut idx = 0usize;
                for b in 0..=num_blocks {
                    let bound = (b * block_size) as u64;
                    while idx < seg.dst_ids.len() && (seg.dst_ids[idx] as u64) < bound {
                        idx += 1;
                    }
                    cur.push(idx as u32);
                }
                cur
            })
            .collect();
        MergePlan {
            block_size,
            num_blocks,
            starts,
        }
    }

    /// Entries (across all segments) that fall in block `b` — the merge
    /// cost estimate for load balancing.
    pub fn block_entries(&self, b: usize) -> u64 {
        self.starts
            .iter()
            .map(|s| (s[b + 1] - s[b]) as u64)
            .sum()
    }

    pub fn bytes(&self) -> usize {
        self.starts.iter().map(|s| s.len() * 4).sum()
    }
}

/// Parallel cache-aware merge: accumulate every segment's sparse updates
/// into `out` (dense). `out` must be pre-initialized; values are added.
// audit: hot-path — the §4.3 merge runs once per iteration; everything
// it touches is caller-owned (hot-path-alloc lint).
pub fn merge(sg: &SegmentedCsr, buffers: &SegmentBuffers, out: &mut [f64]) {
    let plan = &sg.merge_plan;
    let nb = plan.num_blocks;
    let out_slice = UnsafeSlice::new(out);
    let total: u64 = (0..nb).map(|b| plan.block_entries(b)).sum();
    let threshold = (total / (4 * crate::parallel::num_threads() as u64).max(1)).max(512);
    // Each thread usually processes multiple consecutive blocks (§4.3
    // footnote 2), which the range-splitting scheduler provides naturally.
    parallel_for_cost(
        nb,
        threshold,
        |lo, hi| (lo..hi).map(|b| plan.block_entries(b)).sum(),
        |blo, bhi| {
            for b in blo..bhi {
                for (si, (seg, vals)) in sg.segments.iter().zip(&buffers.per_segment).enumerate() {
                    let starts = &plan.starts[si];
                    let i0 = starts[b] as usize;
                    let i1 = starts[b + 1] as usize;
                    // Sequential read of (id, value) pairs; dense write
                    // into the L1-resident output block. Branch-free body;
                    // bounds checks lifted (§Perf change 2).
                    // SAFETY: cursors are within dst_ids/vals by
                    // construction; blocks partition the id range so block
                    // b is owned by exactly one task (no aliased out[d]),
                    // and every d < out.len() by partition construction.
                    unsafe {
                        for i in i0..i1 {
                            let d = *seg.dst_ids.get_unchecked(i) as usize;
                            *out_slice.get_mut(d) += *vals.get_unchecked(i);
                        }
                    }
                }
            }
        },
    );
}
// audit: hot-path-end

/// Serial reference merge (for tests and the merge-cost ablation).
pub fn merge_serial(sg: &SegmentedCsr, buffers: &SegmentBuffers, out: &mut [f64]) {
    for (seg, vals) in sg.segments.iter().zip(&buffers.per_segment) {
        for (i, &d) in seg.dst_ids.iter().enumerate() {
            out[d as usize] += vals[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, Csr};
    use crate::segment::SegmentedCsr;
    use crate::util::prop::check;

    fn setup(seg_size: usize, block: usize) -> (Csr, SegmentedCsr) {
        let (n, edges) = generators::rmat(9, 8, generators::RmatParams::graph500(), 3);
        let g = Csr::from_edges(n, &edges);
        let sg = SegmentedCsr::build_with_block(&g, seg_size, block);
        (g, sg)
    }

    #[test]
    fn plan_cursors_cover_all_entries() {
        let (_, sg) = setup(64, 32);
        let plan = &sg.merge_plan;
        for (s, seg) in sg.segments.iter().enumerate() {
            let st = &plan.starts[s];
            assert_eq!(st[0], 0);
            assert_eq!(*st.last().unwrap() as usize, seg.dst_ids.len());
            // Monotone and consistent with dst_ids.
            for b in 0..plan.num_blocks {
                assert!(st[b] <= st[b + 1]);
                for i in st[b] as usize..st[b + 1] as usize {
                    let id = seg.dst_ids[i] as usize;
                    assert!(id >= b * plan.block_size && id < (b + 1) * plan.block_size);
                }
            }
        }
    }

    #[test]
    fn parallel_merge_matches_serial() {
        let (g, sg) = setup(50, 16);
        let n = g.num_vertices();
        let mut bufs = crate::segment::SegmentBuffers::for_graph(&sg);
        for s in 0..sg.num_segments() {
            let nd = sg.segments[s].num_dsts();
            for i in 0..nd {
                bufs.per_segment[s][i] = (s as f64 + 1.0) * (i as f64 + 0.5);
            }
        }
        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        merge(&sg, &bufs, &mut a);
        merge_serial(&sg, &bufs, &mut b);
        for v in 0..n {
            assert!((a[v] - b[v]).abs() < 1e-12, "v={v}");
        }
    }

    #[test]
    fn block_entries_sum_to_total_adjacent() {
        let (_, sg) = setup(128, 64);
        let total: u64 = (0..sg.merge_plan.num_blocks)
            .map(|b| sg.merge_plan.block_entries(b))
            .sum();
        assert_eq!(total as usize, sg.total_adjacent());
    }

    #[test]
    fn prop_merge_invariant_under_block_size() {
        check("merge independent of block size", 10, |gen| {
            let (n, edges) = gen.edges(2..120, 4);
            let g = Csr::from_edges(n, &edges);
            let seg = gen.usize(1..n + 1);
            let sg1 = SegmentedCsr::build_with_block(&g, seg, 7);
            let sg2 = SegmentedCsr::build_with_block(&g, seg, 4096);
            let mut b1 = crate::segment::SegmentBuffers::for_graph(&sg1);
            let mut b2 = crate::segment::SegmentBuffers::for_graph(&sg2);
            let mut o1 = vec![0.0; n];
            let mut o2 = vec![0.0; n];
            sg1.aggregate(|u| u as f64 + 1.0, &mut b1, 0.0, &mut o1);
            sg2.aggregate(|u| u as f64 + 1.0, &mut b2, 0.0, &mut o2);
            assert_eq!(o1, o2);
        });
    }
}
