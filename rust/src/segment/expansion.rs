//! Expansion factor (paper §4.5, Figure 7).
//!
//! With segment size `s` (vertices) and `s_adj` the average number of
//! vertices adjacent to a segment, the expansion factor `q = s_adj / s`
//! is "how many segments, on average, contribute data to each vertex, and
//! hence how many merge operations happen for each vertex". Table 10's
//! sequential-DRAM-traffic bound for segmenting is `E + 2qV`.

use super::SegmentedCsr;
use crate::graph::Csr;

/// Expansion factor of an already-built segmented graph.
pub fn expansion_factor(sg: &SegmentedCsr) -> f64 {
    if sg.num_segments() == 0 || sg.num_vertices == 0 {
        return 0.0;
    }
    let s_adj = sg.total_adjacent() as f64 / sg.num_segments() as f64;
    s_adj / sg.seg_size as f64
}

/// Compute q for `g` over a sweep of segment counts without storing the
/// full segmented structure (Figure 7's x-axis is "number of segments").
/// Returns `(num_segments, q)` pairs.
pub fn expansion_sweep(g: &Csr, num_segments: &[usize]) -> Vec<(usize, f64)> {
    num_segments
        .iter()
        .map(|&k| {
            let k = k.max(1);
            let seg_size = g.num_vertices().div_ceil(k);
            (k, expansion_for_seg_size(g, seg_size))
        })
        .collect()
}

/// q for a specific segment size, computed via a bitset sweep per segment
/// (memory-light: one pass over edges total).
pub fn expansion_for_seg_size(g: &Csr, seg_size: usize) -> f64 {
    let n = g.num_vertices();
    if n == 0 {
        return 0.0;
    }
    let seg_size = seg_size.max(1);
    let k = n.div_ceil(seg_size);
    let mut total_adjacent = 0u64;
    let mut mark = vec![u32::MAX; n]; // mark[v] = last segment that saw v
    for s in 0..k {
        let lo = s * seg_size;
        let hi = ((s + 1) * seg_size).min(n);
        for u in lo..hi {
            for &v in g.neighbors(u as u32) {
                if mark[v as usize] != s as u32 {
                    mark[v as usize] = s as u32;
                    total_adjacent += 1;
                }
            }
        }
    }
    let s_adj = total_adjacent as f64 / k as f64;
    s_adj / seg_size as f64
}

/// Table 10 traffic models (in vertex-data words): sequential DRAM traffic
/// for each framework given |E|, |V| and its partitioning parameter.
pub mod traffic {
    /// Ours: one pass over edges + 2qV merge traffic (write + read).
    pub fn segmenting(e: u64, v: u64, q: f64) -> f64 {
        e as f64 + 2.0 * q * v as f64
    }

    /// GridGraph: E + (P+2)V with P = partitions per dimension.
    pub fn gridgraph(e: u64, v: u64, p: u64) -> f64 {
        e as f64 + (p as f64 + 2.0) * v as f64
    }

    /// X-Stream: 3E + KV (scatter+shuffle+gather; K = expansion factor of
    /// its streaming partitions).
    pub fn xstream(e: u64, v: u64, k: f64) -> f64 {
        3.0 * e as f64 + k * v as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::segment::SegmentedCsr;
    use crate::util::prop::check;

    #[test]
    fn q_bounds() {
        let (n, edges) = generators::rmat(10, 8, generators::RmatParams::graph500(), 9);
        let g = crate::graph::Csr::from_edges(n, &edges);
        for &k in &[1usize, 2, 4, 8, 16, 64] {
            let seg_size = n.div_ceil(k);
            let sg = SegmentedCsr::build(&g, seg_size);
            let q = expansion_factor(&sg);
            // q ≤ 1 is possible (not all vertices adjacent); upper bounds
            // from the paper: q ≤ k and q ≤ avg degree.
            let avg_deg = g.num_edges() as f64 / n as f64;
            assert!(q >= 0.0);
            assert!(q <= sg.num_segments() as f64 + 1e-9, "q={q} k={k}");
            assert!(q <= avg_deg.max(1.0) + 1e-9, "q={q} avg={avg_deg}");
        }
    }

    #[test]
    fn sweep_matches_built_structure() {
        let (n, edges) = generators::rmat(9, 6, generators::RmatParams::graph500(), 4);
        let g = crate::graph::Csr::from_edges(n, &edges);
        for &k in &[2usize, 4, 8] {
            let seg_size = n.div_ceil(k);
            let sg = SegmentedCsr::build(&g, seg_size);
            let q_fast = expansion_for_seg_size(&g, seg_size);
            let q_built = expansion_factor(&sg);
            assert!(
                (q_fast - q_built).abs() < 1e-12,
                "k={k}: {q_fast} vs {q_built}"
            );
        }
    }

    #[test]
    fn q_monotone_in_segment_count_for_dense_graph() {
        // More segments => each vertex's sources split across more
        // segments => q grows (weakly).
        let (n, edges) = generators::uniform(1 << 9, 1 << 14, 5);
        let g = crate::graph::Csr::from_edges(n, &edges);
        let qs = expansion_sweep(&g, &[1, 2, 4, 8, 16]);
        for w in qs.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-9, "{:?}", qs);
        }
    }

    #[test]
    fn random_order_worse_than_sorted() {
        // Fig 7: "Randomly permuting vertices ... results in a much worse
        // expansion factor" vs degree-sorted.
        let (n, edges) = generators::rmat(11, 16, generators::RmatParams::graph500(), 21);
        let g = crate::graph::Csr::from_edges(n, &edges);
        let (sorted, _) = crate::reorder::reorder(&g, crate::reorder::Ordering::DegreeSort);
        let (random, _) = crate::reorder::reorder(&g, crate::reorder::Ordering::Random);
        let k = 16;
        let seg = n.div_ceil(k);
        let q_sorted = expansion_for_seg_size(&sorted, seg);
        let q_random = expansion_for_seg_size(&random, seg);
        assert!(
            q_sorted < q_random,
            "q_sorted={q_sorted} q_random={q_random}"
        );
    }

    #[test]
    fn traffic_models() {
        // Twitter figures from Table 10: E=36V, q=2.3, P=32.
        let v = 41_000_000u64;
        let e = 36 * v;
        let ours = traffic::segmenting(e, v, 2.3);
        let grid = traffic::gridgraph(e, v, 32);
        let xs = traffic::xstream(e, v, 5.0);
        assert!(ours < grid && grid < xs, "{ours} {grid} {xs}");
    }

    #[test]
    fn prop_q_nonnegative_and_bounded() {
        check("q in [0, min(k, max_deg)]", 15, |gen| {
            let (n, edges) = gen.edges(2..150, 4);
            let g = crate::graph::Csr::from_edges(n, &edges);
            let k = gen.usize(1..n + 1);
            let seg = n.div_ceil(k);
            let q = expansion_for_seg_size(&g, seg);
            assert!(q >= 0.0);
            assert!(q <= n.div_ceil(seg) as f64 + 1e-9);
        });
    }
}
