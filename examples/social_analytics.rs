//! Social-network analytics scenario — the paper's motivating workload
//! mix on one graph: influence (PageRank), reach (BFS), brokerage (BC),
//! community cohesion (triangles), all through the optimized engine.
//!
//! ```text
//! cargo run --release --example social_analytics [-- --graph twitter-sim]
//! ```

use cagra::apps::{bc, bfs, pagerank, pagerank_delta, triangle};
use cagra::coordinator::SystemConfig;
use cagra::graph::datasets;
use cagra::util::cli::Args;
use cagra::util::fmt_count;
use cagra::util::timer::time;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let name = args.get_or("graph", "twitter-sim");
    let scale = args.get_f64("scale", 0.0625);
    let ds = datasets::load_scaled(name, scale)?;
    let g = &ds.graph;
    println!(
        "== social analytics on {name}: {} users, {} follows ==\n",
        fmt_count(g.num_vertices() as u64),
        fmt_count(g.num_edges() as u64)
    );
    let cfg = SystemConfig::default();

    // Influence: PageRank (optimized pipeline) + top-10 influencers.
    let (pr, pr_s) = time(|| pagerank::run(g, &cfg, pagerank::Variant::ReorderedSegmented, 20));
    let mut by_rank: Vec<usize> = (0..g.num_vertices()).collect();
    by_rank.sort_by(|&a, &b| pr.values[b].partial_cmp(&pr.values[a]).unwrap());
    println!("top influencers by PageRank ({pr_s:.2}s for 20 iterations):");
    for &v in by_rank.iter().take(10) {
        println!(
            "  user {v:>8}  rank {:.5}  followers {}",
            pr.values[v],
            fmt_count(g.in_degrees()[v] as u64)
        );
    }

    // Convergence-aware variant: PageRank-Delta.
    let (prd, prd_s) = time(|| pagerank_delta::run(g, &cfg, 1e-4, 100));
    println!(
        "\nPageRank-Delta converged in {} iterations ({prd_s:.2}s); \
         frontier decayed {} -> {}",
        prd.iterations,
        prd.active_history.first().unwrap(),
        prd.active_history.last().unwrap()
    );

    // Reach: BFS from the top influencer.
    let source = by_rank[0] as u32;
    let mut bfs_prep = bfs::Prepared::prepare(
        g,
        &cfg,
        bfs::Variant::ReorderedBitvector,
        &cagra::store::StoreCtx::disabled(),
    );
    let (parents, bfs_s) = time(|| bfs_prep.run(source));
    let reached = parents.iter().filter(|&&p| p != u32::MAX).count();
    println!(
        "\nreach of user {source}: {} of {} vertices ({:.1}%) in {bfs_s:.3}s",
        fmt_count(reached as u64),
        fmt_count(g.num_vertices() as u64),
        reached as f64 / g.num_vertices() as f64 * 100.0
    );

    // Brokerage: betweenness centrality from 4 hub sources.
    let sources = bc::default_sources(g, 4);
    let mut bc_prep = bc::Prepared::prepare(
        g,
        &cfg,
        bc::Variant::ReorderedBitvector,
        &cagra::store::StoreCtx::disabled(),
    );
    let (scores, bc_s) = time(|| bc_prep.run(&sources));
    let mut by_bc: Vec<usize> = (0..g.num_vertices()).collect();
    by_bc.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    println!("\ntop brokers by betweenness ({bc_s:.2}s, {} sources):", sources.len());
    for &v in by_bc.iter().take(5) {
        println!("  user {v:>8}  bc {:.1}", scores[v]);
    }

    // Cohesion: triangle count.
    let (tris, tri_s) = time(|| triangle::count(g));
    println!(
        "\ntriangles: {} ({tri_s:.2}s) — clustering signal for community detection",
        fmt_count(tris)
    );
    println!("\nscenario complete");
    Ok(())
}
