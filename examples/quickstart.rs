//! Quickstart: load a dataset, run PageRank with each optimization, and
//! print the paper-style speedup table.
//!
//! ```text
//! cargo run --release --example quickstart [-- --graph twitter-sim --iters 10]
//! ```

use cagra::apps::pagerank::{self, Variant};
use cagra::bench::table::{fmt_factor, fmt_secs, Table};
use cagra::coordinator::SystemConfig;
use cagra::graph::datasets;
use cagra::util::cli::Args;
use cagra::util::fmt_count;
use cagra::util::timer::time;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let graph_name = args.get_or("graph", "livejournal-sim");
    let iters = args.get_usize("iters", 10);
    let scale = args.get_f64("scale", 0.25);

    println!("== Cagra quickstart ==");
    let ds = datasets::load_scaled(graph_name, scale)?;
    let g = &ds.graph;
    println!(
        "{graph_name}: {} vertices, {} edges (stand-in for {})\n",
        fmt_count(g.num_vertices() as u64),
        fmt_count(g.num_edges() as u64),
        datasets::paper_name(graph_name)
    );

    let cfg = SystemConfig::default();
    let mut rows: Vec<(String, f64)> = Vec::new();
    for &variant in Variant::all() {
        let mut prep = pagerank::Prepared::prepare(g, &cfg, variant, &cagra::store::StoreCtx::disabled());
        prep.reset();
        // Warm one iteration, then time the rest.
        prep.step();
        let (_, secs) = time(|| {
            for _ in 0..iters {
                prep.step();
            }
        });
        rows.push((variant.name().to_string(), secs / iters as f64));
    }

    let base = rows[0].1;
    let mut table = Table::new(&["Variant", "Per-iteration", "Speedup vs baseline"]);
    for (name, secs) in &rows {
        table.row(&[name.clone(), fmt_secs(*secs), fmt_factor(base / secs)]);
    }
    table.print();

    // Cross-check: all variants agree with the reference.
    let want = pagerank::reference(g, cfg.damping, 3);
    for &variant in Variant::all() {
        let got = pagerank::run(g, &cfg, variant, 3);
        let max_rel = got
            .values
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs() / b.abs().max(1e-12))
            .fold(0.0f64, f64::max);
        assert!(max_rel < 1e-9, "{}: {max_rel}", variant.name());
    }
    println!("\nall variants verified against the reference (<=1e-9 rel)");
    Ok(())
}
