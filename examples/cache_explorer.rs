//! Cache explorer: sweep segment sizes and orderings, reporting expansion
//! factors, simulated miss rates, and the §5 analytical model side by
//! side — the tooling a user needs to size segments for a new machine.
//!
//! ```text
//! cargo run --release --example cache_explorer [-- --graph twitter-sim]
//! ```

use cagra::bench::table::Table;
use cagra::cache::model::{predicted_miss_rate, CacheGeometry};
use cagra::cache::sim::CacheSim;
use cagra::cache::trace;
use cagra::coordinator::SystemConfig;
use cagra::graph::datasets;
use cagra::reorder::{self, Ordering as VOrdering};
use cagra::segment::expansion;
use cagra::util::cli::Args;
use cagra::util::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let name = args.get_or("graph", "twitter-sim");
    let scale = args.get_f64("scale", 0.125);
    let ds = datasets::load_scaled(name, scale)?;
    let g = &ds.graph;
    let n = g.num_vertices();
    println!(
        "== cache explorer: {name} ({} vertices, {} edges) ==\n",
        n,
        g.num_edges()
    );

    // 1. Expansion factor vs segment count per ordering (Figure 7 logic).
    println!("expansion factor q by segment count (Figure 7):");
    let counts = [1usize, 2, 4, 8, 16, 32, 64];
    let mut t = Table::new(&["ordering", "1", "2", "4", "8", "16", "32", "64"]);
    for &o in &[VOrdering::Identity, VOrdering::DegreeSort, VOrdering::Random] {
        let (h, _) = reorder::reorder(g, o);
        let sweep = expansion::expansion_sweep(&h, &counts);
        let mut row = vec![o.name().to_string()];
        row.extend(sweep.iter().map(|(_, q)| format!("{q:.2}")));
        t.row(&row);
    }
    t.print();

    // 2. Simulated vs analytical miss rate for the random vertex stream.
    println!("\nvertex-stream miss rate: simulator vs analytical model (Section 5):");
    let mut t = Table::new(&["ordering", "cache", "simulated", "model", "|err|"]);
    for &o in &[VOrdering::Identity, VOrdering::DegreeSort, VOrdering::Random] {
        let (h, _) = reorder::reorder(g, o);
        let pull = h.transpose();
        let stream = trace::vertex_trace(&pull, 8, (g.num_edges() / 400_000).max(1));
        let weights: Vec<u64> = h.out_degrees().iter().map(|&d| d as u64).collect();
        for kib in [64usize, 256] {
            let geom = CacheGeometry::new(kib * 1024, 16, 64);
            let mut sim = CacheSim::new(geom);
            for &a in &stream {
                sim.access(a);
            }
            let model = predicted_miss_rate(&weights, 8, geom);
            t.row(&[
                o.name().to_string(),
                fmt_bytes(kib * 1024),
                format!("{:.1}%", sim.miss_rate() * 100.0),
                format!("{:.1}%", model * 100.0),
                format!("{:.1}pp", (sim.miss_rate() - model).abs() * 100.0),
            ]);
        }
    }
    t.print();

    // 3. Segment-size tradeoff: stalls vs merge traffic (Section 4.5).
    println!("\nsegment-size tradeoff (stall model, default hierarchy):");
    let cfg = SystemConfig::default();
    let mut t = Table::new(&["seg vertices", "segments", "q", "stall-cyc/access"]);
    for shift in [10usize, 12, 14, 16] {
        let seg = (1usize << shift).min(n);
        let sg = cagra::segment::SegmentedCsr::build(g, seg);
        let est = cagra::cache::stall::estimate_segmented_iteration(
            &sg,
            8,
            cfg.llc_bytes,
            (g.num_edges() / 400_000).max(1),
        );
        t.row(&[
            format!("{seg}"),
            format!("{}", sg.num_segments()),
            format!("{:.2}", expansion::expansion_factor(&sg)),
            format!("{:.2}", est.stalls_per_access()),
        ]);
        if seg >= n {
            break;
        }
    }
    t.print();
    println!(
        "\nrecommended segment size for {} effective LLC: {} vertices",
        fmt_bytes(cfg.llc_bytes),
        cfg.segment_size(8)
    );
    Ok(())
}
