//! End-to-end driver: proves all three layers compose on a real small
//! workload (EXPERIMENTS.md §End-to-end records a run).
//!
//! 1. L3 generates an RMAT graph and runs the full native pipeline
//!    (reorder + segment) to PageRank convergence.
//! 2. The same graph is fed through the **PJRT path**: the
//!    `pagerank_step` artifact (Pallas L1 kernel inside a JAX L2 graph,
//!    AOT-lowered at build time) is executed from rust per iteration and
//!    cross-validated against the native engine.
//! 3. A Collaborative-Filtering model is trained for several hundred
//!    steps through the `cf_step` artifact, logging the loss curve.
//!
//! ```text
//! make artifacts && cargo run --release --example end_to_end
//! ```

use cagra::apps::pagerank;
use cagra::coordinator::SystemConfig;
use cagra::graph::{generators, CsrBuilder, VertexId};
use cagra::runtime::Runtime;
use cagra::util::timer::time;

fn main() -> anyhow::Result<()> {
    println!("== Cagra end-to-end (L1 Pallas + L2 JAX + L3 rust) ==\n");
    let mut rt = Runtime::from_env()?;
    println!("PJRT platform: {}", rt.platform());

    // ---------------------------------------------------------- PageRank
    let exe = rt.load("pagerank_step")?;
    let n = exe.meta.param_usize("n")?;
    println!("\n[1/3] native pipeline, {n}-vertex RMAT graph");
    let (_, edges) = generators::rmat(
        n.trailing_zeros(),
        8,
        generators::RmatParams::graph500(),
        2024,
    );
    let mut b = CsrBuilder::new(n);
    b.extend(edges);
    let g = b.build();
    let cfg = SystemConfig {
        llc_bytes: 64 * 1024, // scaled so this small graph still segments
        ..Default::default()
    };
    let mut prep = pagerank::Prepared::prepare(
        &g,
        &cfg,
        pagerank::Variant::ReorderedSegmented,
        &cagra::store::StoreCtx::disabled(),
    );
    let iters = 30;
    let (native, native_s) = time(|| prep.run(iters));
    println!(
        "    native reorder+segment: {iters} iterations in {native_s:.3}s \
         ({:.2} MEdge/s)",
        g.num_edges() as f64 * iters as f64 / native_s / 1e6
    );

    println!("\n[2/3] same graph through the PJRT artifact (L1+L2)");
    let mut a = vec![0.0f32; n * n];
    for (u, v) in g.edges() {
        a[v as usize * n + u as usize] = 1.0;
    }
    let inv: Vec<f32> = (0..n)
        .map(|u| {
            let d = g.degree(u as VertexId);
            if d == 0 {
                0.0
            } else {
                1.0 / d as f32
            }
        })
        .collect();
    let mut rank = vec![1.0 / n as f32; n];
    let exe = rt.load("pagerank_step")?;
    let (_, pjrt_s) = time(|| {
        for _ in 0..iters {
            let out = exe
                .run_f32(&[(&a, &[n, n]), (&rank, &[n]), (&inv, &[n])])
                .expect("pagerank_step execution");
            rank = out[0].clone();
        }
    });
    let max_rel = rank
        .iter()
        .zip(&native.values)
        .map(|(x, y)| (*x as f64 - y).abs() / y.abs().max(1e-9))
        .fold(0.0f64, f64::max);
    println!(
        "    PJRT: {iters} iterations in {pjrt_s:.3}s; max rel err vs native = {max_rel:.2e}"
    );
    assert!(max_rel < 1e-3, "cross-layer validation failed");
    println!("    cross-layer numerics VERIFIED (rust CSR engine == Pallas tile kernel)");

    // ---------------------------------------------------------------- CF
    println!("\n[3/3] CF training through the cf_step artifact");
    let exe = rt.load("cf_step")?;
    let nu = exe.meta.param_usize("nu")?;
    let ni = exe.meta.param_usize("ni")?;
    let k = exe.meta.param_usize("k")?;
    // Plant a rank-k ground truth so the loss curve has signal.
    let mut rng = cagra::util::rng::Rng::new(42);
    let truth_u: Vec<f32> = (0..nu * k).map(|_| rng.next_f32()).collect();
    let truth_v: Vec<f32> = (0..ni * k).map(|_| rng.next_f32()).collect();
    let mut r = vec![0.0f32; nu * ni];
    let mut mask = vec![0.0f32; nu * ni];
    let mut observed = 0usize;
    for uu in 0..nu {
        for _ in 0..12 {
            let ii = rng.next_below(ni as u64) as usize;
            let dot: f32 = (0..k).map(|j| truth_u[uu * k + j] * truth_v[ii * k + j]).sum();
            if mask[uu * ni + ii] == 0.0 {
                observed += 1;
            }
            r[uu * ni + ii] = dot;
            mask[uu * ni + ii] = 1.0;
        }
    }
    let mut u: Vec<f32> = (0..nu * k).map(|_| rng.next_f32() * 0.2).collect();
    let mut v: Vec<f32> = (0..ni * k).map(|_| rng.next_f32() * 0.2).collect();
    let steps = 300;
    let mut curve: Vec<(usize, f64)> = Vec::new();
    let (_, train_s) = time(|| {
        for step in 0..steps {
            let out = exe
                .run_f32(&[
                    (&u, &[nu, k]),
                    (&v, &[ni, k]),
                    (&r, &[nu, ni]),
                    (&mask, &[nu, ni]),
                ])
                .expect("cf_step execution");
            u = out[0].clone();
            v = out[1].clone();
            let rmse = (out[2][0] as f64 / observed as f64).sqrt();
            if step % 30 == 0 || step == steps - 1 {
                curve.push((step, rmse));
            }
        }
    });
    println!("    {steps} GD steps in {train_s:.1}s ({nu} users x {ni} items, k={k})");
    println!("    loss curve (step, RMSE):");
    for (s, rmse) in &curve {
        println!("      {s:>4}  {rmse:.4}");
    }
    let first = curve.first().unwrap().1;
    let last = curve.last().unwrap().1;
    assert!(
        last < first * 0.5,
        "training failed to descend: {first} -> {last}"
    );
    println!("\nend-to-end PASSED: loss {first:.4} -> {last:.4}");
    Ok(())
}
