//! Perf probe: min-of-runs per-iteration timing for the optimized
//! PageRank pipeline (used by the EXPERIMENTS.md §Perf log).
//! PROBE_LLC overrides the effective-LLC sizing.
use cagra::apps::pagerank::{Prepared, Variant};
use cagra::coordinator::SystemConfig;
fn main() {
    let ds = cagra::graph::datasets::load("rmat27-sim").unwrap();
    let llc: usize = std::env::var("PROBE_LLC").ok().and_then(|v| v.parse().ok()).unwrap_or(2*1024*1024);
    let cfg = SystemConfig { llc_bytes: llc, ..Default::default() };
    let mut p = Prepared::prepare(&ds.graph, &cfg, Variant::ReorderedSegmented, &cagra::store::StoreCtx::disabled());
    p.reset();
    p.step(); // warm
    let mut best = f64::INFINITY;
    for _ in 0..5 {
        let iters = 8;
        let t0 = std::time::Instant::now();
        for _ in 0..iters { p.step(); }
        best = best.min(t0.elapsed().as_secs_f64() / iters as f64);
    }
    println!("segmented+reordered (min of 5x8): {:.2}ms/iter  {:.2}ns/edge", best*1e3, best/ds.graph.num_edges() as f64*1e9);
}
