"""Layer-2 JAX model: the compute graphs the rust coordinator executes via
PJRT. Each function is jitted, calls the L1 Pallas kernels, and is lowered
once by aot.py to HLO text.

Shapes are fixed at AOT time (one artifact per configuration); the
defaults match the end_to_end example's 2048-vertex demo graph.
"""

import jax
import jax.numpy as jnp

from .kernels import cf_block, segment_spmv

# Static configuration baked into the default artifacts.
PAGERANK_N = 2048
PAGERANK_TILE = 256
PAGERANK_DAMPING = 0.85

CF_NU = 512
CF_NI = 256
CF_K = 8
CF_TILE_U = 128
CF_TILE_I = 128
CF_LR = 0.02


def pagerank_step(a, rank, inv_deg):
    """One PageRank pull iteration over the dense segment-tiled adjacency.

    a: (n, n) with a[v, u] = 1.0 iff u -> v; rank, inv_deg: (n,).
    Returns the 1-tuple (new_rank,) (lowered with return_tuple=True).
    """
    n = rank.shape[0]
    contrib = rank * inv_deg  # the paper's contribution precompute
    agg = segment_spmv.matvec(a, contrib, tile_d=PAGERANK_TILE, tile_s=PAGERANK_TILE)
    new_rank = (1.0 - PAGERANK_DAMPING) / n + PAGERANK_DAMPING * agg
    return (new_rank,)


def cf_step(u, v, r, mask):
    """One CF gradient-descent step (Jacobi: both sides from old values).

    Returns (u', v', sse).
    """
    du, dv, sse = cf_block.cf_grads(u, v, r, mask, tile_u=CF_TILE_U, tile_i=CF_TILE_I)
    return (u - CF_LR * du, v - CF_LR * dv, sse)


def pagerank_example_args(n=PAGERANK_N):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n, n), f32),
        jax.ShapeDtypeStruct((n,), f32),
        jax.ShapeDtypeStruct((n,), f32),
    )


def cf_example_args(nu=CF_NU, ni=CF_NI, k=CF_K):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((nu, k), f32),
        jax.ShapeDtypeStruct((ni, k), f32),
        jax.ShapeDtypeStruct((nu, ni), f32),
        jax.ShapeDtypeStruct((nu, ni), f32),
    )
