"""AOT lowering: jit → lower → StableHLO → XlaComputation → **HLO text**.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each artifact gets a ``<name>.hlo.txt`` plus a ``<name>.meta`` sidecar
(shapes + static params) the rust runtime parses.

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dims(shape) -> str:
    if not shape:
        return "scalar"
    return "x".join(str(d) for d in shape)


def emit(out_dir, name, fn, example_args, out_shapes, params):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)
    meta_lines = ["[shapes]"]
    for i, arg in enumerate(example_args):
        meta_lines.append(f"input{i} = {_dims(arg.shape)}")
    for i, shape in enumerate(out_shapes):
        meta_lines.append(f"output{i} = {_dims(shape)}")
    meta_lines.append("[params]")
    for k, v in params.items():
        meta_lines.append(f"{k} = {v}")
    with open(os.path.join(out_dir, f"{name}.meta"), "w") as f:
        f.write("\n".join(meta_lines) + "\n")
    print(f"wrote {hlo_path} ({len(text)} chars)")


def build_all(out_dir):
    os.makedirs(out_dir, exist_ok=True)
    n = model.PAGERANK_N
    emit(
        out_dir,
        "pagerank_step",
        model.pagerank_step,
        model.pagerank_example_args(),
        out_shapes=[(n,)],
        params={
            "n": n,
            "tile": model.PAGERANK_TILE,
            "damping": model.PAGERANK_DAMPING,
        },
    )
    nu, ni, k = model.CF_NU, model.CF_NI, model.CF_K
    emit(
        out_dir,
        "cf_step",
        model.cf_step,
        model.cf_example_args(),
        out_shapes=[(nu, k), (ni, k), ()],
        params={
            "nu": nu,
            "ni": ni,
            "k": k,
            "lr": model.CF_LR,
            "tile_u": model.CF_TILE_U,
            "tile_i": model.CF_TILE_I,
        },
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build_all(args.out_dir)


if __name__ == "__main__":
    main()
