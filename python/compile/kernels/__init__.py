"""Layer-1 Pallas kernels (build-time only; never imported at runtime).

The paper's hot spot -- aggregate contributions over a cache-resident
segment of source-vertex data -- becomes, on TPU-shaped hardware, a tiled
dense mat-vec whose x-tiles are pinned in VMEM (DESIGN.md
``Hardware-Adaptation``). ``segment_spmv`` is that kernel; ``cf_block`` is
the Collaborative-Filtering block-gradient kernel; ``ref`` holds the
pure-jnp oracles pytest checks them against.
"""
