"""Segmented mat-vec as a Pallas kernel (the L1 hot spot).

CSR segmenting's TPU translation (DESIGN.md Hardware-Adaptation): the
randomly-read source-vertex slice becomes the x-tile pinned in VMEM while
(TILE_D, TILE_S) adjacency tiles stream in from HBM and hit the MXU. The
grid's inner dimension walks source tiles — exactly the paper's
"one segment at a time" schedule — and accumulates into the output tile,
which is the cache-aware-merge analogue (the partial sums never leave
VMEM between segment steps).

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; numerics are validated through the interpret path and the
lowered HLO is what the rust runtime executes.

VMEM budget at the default TILE=256, f32:
    A tile   256*256*4  = 256 KiB
    x tile   256*4      =   1 KiB
    y tile   256*4      =   1 KiB
well under ~16 MiB VMEM; the MXU sees (256x256)@(256x1) per step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matvec_kernel(a_ref, x_ref, o_ref):
    """One (dst-tile, src-tile) grid step: o += A_tile @ x_tile."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU-shaped block product; x is kept (TILE_S, 1) so this is a matmul,
    # not a reduction loop.
    o_ref[...] += jnp.dot(
        a_ref[...], x_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("tile_d", "tile_s"))
def matvec(a, x, tile_d=256, tile_s=256):
    """y = A @ x with segment-tiled accumulation.

    a: (n_dst, n_src); x: (n_src,). Dimensions must divide the tiles.
    """
    n_dst, n_src = a.shape
    assert n_dst % tile_d == 0, f"n_dst {n_dst} % tile_d {tile_d}"
    assert n_src % tile_s == 0, f"n_src {n_src} % tile_s {tile_s}"
    x2 = x.reshape(n_src, 1)
    grid = (n_dst // tile_d, n_src // tile_s)
    y2 = pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_d, tile_s), lambda i, j: (i, j)),
            pl.BlockSpec((tile_s, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_d, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_dst, 1), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a, x2)
    return y2.reshape(n_dst)


def vmem_bytes(tile_d=256, tile_s=256, dtype_bytes=4):
    """Static VMEM footprint of one grid step (for DESIGN.md §Perf)."""
    a = tile_d * tile_s * dtype_bytes
    x = tile_s * dtype_bytes
    y = tile_d * dtype_bytes
    return a + x + y


def mxu_utilization_estimate(tile_d=256, tile_s=256):
    """Fraction of 128x128-systolic-array issue slots a (tile_d, tile_s)
    @ (tile_s, 1) product can fill. Mat-vec feeds one output column, so
    the dense-matmul bound is 1/128 per pass — the kernel compensates by
    batching dst tiles; reported for the §Perf roofline discussion."""
    mxu = 128
    fill_rows = min(tile_d, mxu) / mxu
    fill_cols = 1 / mxu  # single output column
    return fill_rows * fill_cols
