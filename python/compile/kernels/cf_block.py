"""Collaborative-Filtering block-gradient Pallas kernel.

One grid step owns a (TILE_U, TILE_I) block of the rating matrix:

    P   = U_blk @ V_blk^T          (TILE_U, K) @ (K, TILE_I)  -- MXU
    E   = (P - R_blk) * mask
    dU += E @ V_blk                (TILE_U, TILE_I) @ (TILE_I, K)
    dV += E^T @ U_blk              (TILE_I, TILE_U) @ (TILE_U, K)

The latent factors are the segment-resident working set (the paper's CF
working set is "per-vertex latent factor vectors"); rating blocks stream.
Accumulation across the grid's streaming dimension keeps dU/dV in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cf_kernel(u_ref, v_ref, r_ref, m_ref, du_ref, dv_ref, sse_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init_du():
        du_ref[...] = jnp.zeros_like(du_ref)

    @pl.when(i == 0)
    def _init_dv():
        dv_ref[...] = jnp.zeros_like(dv_ref)

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _init_sse():
        sse_ref[...] = jnp.zeros_like(sse_ref)

    u = u_ref[...]
    v = v_ref[...]
    pred = jnp.dot(u, v.T, preferred_element_type=r_ref.dtype)
    err = (pred - r_ref[...]) * m_ref[...]
    du_ref[...] += jnp.dot(err, v, preferred_element_type=du_ref.dtype)
    dv_ref[...] += jnp.dot(err.T, u, preferred_element_type=dv_ref.dtype)
    sse_ref[...] += jnp.sum(err * err)


@functools.partial(jax.jit, static_argnames=("tile_u", "tile_i"))
def cf_grads(u, v, r, mask, tile_u=128, tile_i=128):
    """Masked-MF gradients, block-tiled. Returns (dU, dV, sse)."""
    nu, k = u.shape
    ni, k2 = v.shape
    assert k == k2
    assert r.shape == (nu, ni) and mask.shape == (nu, ni)
    assert nu % tile_u == 0 and ni % tile_i == 0
    grid = (nu // tile_u, ni // tile_i)
    du, dv, sse = pl.pallas_call(
        _cf_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_u, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_i, k), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_u, tile_i), lambda i, j: (i, j)),
            pl.BlockSpec((tile_u, tile_i), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((tile_u, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_i, k), lambda i, j: (j, 0)),
            # Scalar accumulator: a (1, 1) block every step maps to.
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nu, k), u.dtype),
            jax.ShapeDtypeStruct((ni, k), v.dtype),
            jax.ShapeDtypeStruct((1, 1), u.dtype),
        ],
        interpret=True,
    )(u, v, r, mask)
    return du, dv, sse[0, 0]


def vmem_bytes(tile_u=128, tile_i=128, k=8, dtype_bytes=4):
    """Static VMEM footprint of one grid step."""
    return dtype_bytes * (
        tile_u * k  # U tile
        + tile_i * k  # V tile
        + 2 * tile_u * tile_i  # R + mask
        + tile_u * k  # dU accumulator
        + tile_i * k  # dV accumulator
    )
