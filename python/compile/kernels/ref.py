"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Everything here is straight-line jax.numpy with no tiling, no pallas, no
cleverness; pytest asserts the kernels match these to tight tolerances.
"""

import jax.numpy as jnp


def matvec(a, x):
    """y = A @ x for A (n_dst, n_src), x (n_src,)."""
    return a @ x


def pagerank_step(a, rank, inv_deg, damping):
    """One PageRank pull iteration over a dense adjacency.

    a[v, u] = 1.0 iff edge u -> v; contributions are rank * inv_deg.
    """
    n = rank.shape[0]
    contrib = rank * inv_deg
    agg = a @ contrib
    return (1.0 - damping) / n + damping * agg


def cf_grads(u, v, r, mask):
    """Gradients of 0.5 * sum(mask * (U V^T - R)^2) w.r.t. U and V.

    u: (nu, k), v: (ni, k), r/mask: (nu, ni).
    Returns (grad_u, grad_v, sse).
    """
    pred = u @ v.T
    err = (pred - r) * mask
    grad_u = err @ v
    grad_v = err.T @ u
    sse = jnp.sum(err * err)
    return grad_u, grad_v, sse


def cf_step(u, v, r, mask, lr):
    """One Jacobi gradient-descent step; returns (u', v', sse)."""
    grad_u, grad_v, sse = cf_grads(u, v, r, mask)
    return u - lr * grad_u, v - lr * grad_v, sse
