"""L2 correctness: model steps vs references, shape checks, and AOT
round-trips (HLO text parses and contains the entry computation)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref


def small_graph_dense(n, seed=0):
    """Random dense adjacency a[v, u] plus inv_deg, f32."""
    rng = np.random.default_rng(seed)
    a = (rng.random((n, n)) < (8.0 / n)).astype(np.float32)
    np.fill_diagonal(a, 0.0)
    out_deg = a.sum(axis=0)  # column sums: out-degree of u
    inv = np.where(out_deg > 0, 1.0 / np.maximum(out_deg, 1e-30), 0.0).astype(np.float32)
    return jnp.asarray(a), jnp.asarray(inv)


def test_pagerank_step_matches_ref():
    n = model.PAGERANK_N
    a, inv = small_graph_dense(n, seed=1)
    rank = jnp.full((n,), 1.0 / n, jnp.float32)
    (got,) = model.pagerank_step(a, rank, inv)
    want = ref.pagerank_step(a, rank, inv, model.PAGERANK_DAMPING)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-7)


def test_pagerank_step_mass_bounded():
    n = model.PAGERANK_N
    a, inv = small_graph_dense(n, seed=2)
    rank = jnp.full((n,), 1.0 / n, jnp.float32)
    for _ in range(5):
        (rank,) = model.pagerank_step(a, rank, inv)
    total = float(jnp.sum(rank))
    assert 0.0 < total <= 1.0 + 1e-4


def test_cf_step_reduces_sse():
    rng = np.random.default_rng(4)
    u = jnp.asarray(rng.standard_normal((model.CF_NU, model.CF_K)) * 0.1, jnp.float32)
    v = jnp.asarray(rng.standard_normal((model.CF_NI, model.CF_K)) * 0.1, jnp.float32)
    r = jnp.asarray(rng.random((model.CF_NU, model.CF_NI)) * 4 + 1, jnp.float32)
    mask = jnp.asarray(rng.random((model.CF_NU, model.CF_NI)) < 0.05, jnp.float32)
    u1, v1, sse0 = model.cf_step(u, v, r, mask)
    _, _, sse1 = model.cf_step(u1, v1, r, mask)
    assert float(sse1) < float(sse0)


def test_cf_step_matches_ref():
    rng = np.random.default_rng(5)
    u = jnp.asarray(rng.standard_normal((model.CF_NU, model.CF_K)) * 0.1, jnp.float32)
    v = jnp.asarray(rng.standard_normal((model.CF_NI, model.CF_K)) * 0.1, jnp.float32)
    r = jnp.asarray(rng.random((model.CF_NU, model.CF_NI)), jnp.float32)
    mask = jnp.asarray(rng.random((model.CF_NU, model.CF_NI)) < 0.1, jnp.float32)
    u1, v1, sse = model.cf_step(u, v, r, mask)
    ru, rv, rsse = ref.cf_step(u, v, r, mask, model.CF_LR)
    np.testing.assert_allclose(np.asarray(u1), np.asarray(ru), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(rv), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(sse), float(rsse), rtol=1e-4)


def test_aot_hlo_text_roundtrip(tmp_path):
    """Lower a tiny pagerank-shaped fn and check the HLO text parses back
    (entry computation present, ROOT tuple of the right arity)."""
    n = 64

    def tiny(a, rank, inv):
        from compile.kernels import segment_spmv

        contrib = rank * inv
        agg = segment_spmv.matvec(a, contrib, tile_d=16, tile_s=16)
        return ((1.0 - 0.85) / n + 0.85 * agg,)

    spec = jax.ShapeDtypeStruct
    lowered = jax.jit(tiny).lower(
        spec((n, n), jnp.float32), spec((n,), jnp.float32), spec((n,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    assert "f32[64,64]" in text  # input shape survived
    # And the real emit() writes both files with parseable meta.
    aot.emit(
        str(tmp_path),
        "tiny",
        tiny,
        (spec((n, n), jnp.float32), spec((n,), jnp.float32), spec((n,), jnp.float32)),
        out_shapes=[(n,)],
        params={"n": n},
    )
    assert (tmp_path / "tiny.hlo.txt").exists()
    meta = (tmp_path / "tiny.meta").read_text()
    assert "input0 = 64x64" in meta
    assert "output0 = 64" in meta
    assert "n = 64" in meta


def test_example_args_shapes():
    args = model.pagerank_example_args()
    assert args[0].shape == (model.PAGERANK_N, model.PAGERANK_N)
    cf_args = model.cf_example_args()
    assert cf_args[2].shape == (model.CF_NU, model.CF_NI)
