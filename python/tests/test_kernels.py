"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes per the repo's testing contract.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cf_block, ref, segment_spmv

jax.config.update("jax_enable_x64", False)


def rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


TOL = {jnp.float32: 1e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------- matvec


@settings(max_examples=20, deadline=None)
@given(
    dst_tiles=st.integers(1, 4),
    src_tiles=st.integers(1, 4),
    tile=st.sampled_from([8, 16, 32]),
    dtype_i=st.sampled_from([0, 1]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_matches_ref(dst_tiles, src_tiles, tile, dtype_i, seed):
    dtype = [jnp.float32, jnp.bfloat16][dtype_i]
    rng = np.random.default_rng(seed)
    n_dst, n_src = dst_tiles * tile, src_tiles * tile
    a = rand(rng, (n_dst, n_src), dtype)
    x = rand(rng, (n_src,), dtype)
    got = segment_spmv.matvec(a, x, tile_d=tile, tile_s=tile)
    want = ref.matvec(a.astype(jnp.float32), x.astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32),
        np.asarray(want),
        rtol=TOL[dtype],
        atol=TOL[dtype] * np.sqrt(n_src),
    )


def test_matvec_rejects_ragged_shapes():
    a = jnp.zeros((100, 64), jnp.float32)
    x = jnp.zeros((64,), jnp.float32)
    with pytest.raises(AssertionError):
        segment_spmv.matvec(a, x, tile_d=64, tile_s=64)


def test_matvec_identity():
    n = 64
    a = jnp.eye(n, dtype=jnp.float32)
    x = jnp.arange(n, dtype=jnp.float32)
    got = segment_spmv.matvec(a, x, tile_d=16, tile_s=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x))


def test_matvec_tile_independence():
    rng = np.random.default_rng(7)
    a = rand(rng, (128, 128), jnp.float32)
    x = rand(rng, (128,), jnp.float32)
    y8 = segment_spmv.matvec(a, x, tile_d=8, tile_s=8)
    y64 = segment_spmv.matvec(a, x, tile_d=64, tile_s=64)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y64), rtol=2e-5, atol=1e-5)


def test_vmem_budget_documented():
    # The default tiles must stay far under a 16 MiB VMEM.
    assert segment_spmv.vmem_bytes(256, 256) < 1 << 20
    assert cf_block.vmem_bytes(128, 128, 8) < 1 << 20


# ------------------------------------------------------------------- cf


@settings(max_examples=15, deadline=None)
@given(
    u_tiles=st.integers(1, 3),
    i_tiles=st.integers(1, 3),
    tile=st.sampled_from([8, 16]),
    k=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_cf_grads_match_ref(u_tiles, i_tiles, tile, k, seed):
    rng = np.random.default_rng(seed)
    nu, ni = u_tiles * tile, i_tiles * tile
    u = rand(rng, (nu, k), jnp.float32)
    v = rand(rng, (ni, k), jnp.float32)
    r = rand(rng, (nu, ni), jnp.float32)
    mask = jnp.asarray(rng.random((nu, ni)) < 0.3, dtype=jnp.float32)
    du, dv, sse = cf_block.cf_grads(u, v, r, mask, tile_u=tile, tile_i=tile)
    rdu, rdv, rsse = ref.cf_grads(u, v, r, mask)
    np.testing.assert_allclose(np.asarray(du), np.asarray(rdu), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(rdv), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(sse), float(rsse), rtol=1e-4)


def test_cf_zero_mask_zero_grads():
    rng = np.random.default_rng(3)
    u = rand(rng, (16, 8), jnp.float32)
    v = rand(rng, (16, 8), jnp.float32)
    r = rand(rng, (16, 16), jnp.float32)
    mask = jnp.zeros((16, 16), jnp.float32)
    du, dv, sse = cf_block.cf_grads(u, v, r, mask, tile_u=8, tile_i=8)
    assert float(jnp.abs(du).max()) == 0.0
    assert float(jnp.abs(dv).max()) == 0.0
    assert float(sse) == 0.0


def test_cf_descent_reduces_loss():
    rng = np.random.default_rng(5)
    u = rand(rng, (32, 8), jnp.float32) * 0.1
    v = rand(rng, (32, 8), jnp.float32) * 0.1
    r = jnp.asarray(rng.random((32, 32)) * 4 + 1, dtype=jnp.float32)
    mask = jnp.asarray(rng.random((32, 32)) < 0.5, dtype=jnp.float32)
    lr = 0.01
    _, _, sse0 = cf_block.cf_grads(u, v, r, mask, tile_u=16, tile_i=16)
    for _ in range(10):
        du, dv, _ = cf_block.cf_grads(u, v, r, mask, tile_u=16, tile_i=16)
        u = u - lr * du
        v = v - lr * dv
    _, _, sse1 = cf_block.cf_grads(u, v, r, mask, tile_u=16, tile_i=16)
    assert float(sse1) < float(sse0)
